"""Shared-memory ring-buffer channel for same-host courier traffic.

Two nodes the process launcher placed on one host still paid the full gRPC
stack for every call (~2000x the in-process cost for a ping — see
BENCH_rpc.json). This module moves framed courier messages between
same-host processes over ``multiprocessing.shared_memory`` instead:

* **Ring** — one SPSC byte ring per direction. The writer owns ``wpos``,
  the reader owns ``rpos`` (each on its own cache line, published after
  the payload), so neither side ever takes a cross-process lock on the
  data path. Records are length-prefixed and contiguous; a record that
  would straddle the wrap point is preceded by a pad record both sides
  skip deterministically.
* **Bulk spill slots** — a message larger than ``SPILL_THRESHOLD`` is
  scatter-gathered (``serialization.write_framed_into``) into a
  per-direction *bulk slot* side segment and only a tiny reference
  record enters the control ring, so the ring stays small while 8 MiB
  tensors move at memcpy speed. The slot is created lazily, reused for
  the connection's lifetime (segment creation and first-touch page
  faults cost milliseconds on the kernels we deploy on), grown
  geometrically when a bigger message arrives, and always written at a
  *fixed* offset — cycling a multi-MiB ring through the cache measures
  ~3x slower than rewriting one hot region. One large message per
  direction is in flight at a time (seq_written/seq_consumed handshake);
  the writer only waits until the reader has *copied* the message out,
  so compute still overlaps transfer.
* **Doorbell** — waiting sides use an adaptive spin-then-micro-sleep loop
  (a portable stand-in for a futex: hot peers rendezvous in microseconds,
  idle peers cost ~0 CPU). Position loads/stores are 8-byte aligned, so
  they are single movs on x86-64/arm64 — published last, read first.
* **Rendezvous** — a server advertises under
  ``$TMPDIR/courier-shm/<name>/listener.json``; a client creates the two
  rings, drops a ``<conn>.connect`` file, and waits for the listener's
  HELLO record. Liveness is pid-based: a stale directory left by a
  crashed server is detected immediately (``probe`` -> "stale") so
  callers can fall back to gRPC instead of deadlocking.

Record layout (little-endian)::

    size:u32 | kind:u32 | req_id:u64 | body[size - 16]

``size == 0`` marks a pad record (skip to the wrap point). The body is a
standard framed serialization message, or a spill reference::

    \xc5\x02 | name_len:u16 | segment_name | total:u64
"""

from __future__ import annotations

import contextlib
import glob
import json
import os
import socket
import struct
import tempfile
import threading
import time
import uuid
from concurrent import futures
from multiprocessing import resource_tracker, shared_memory
from typing import Any, Callable, Optional

from repro.core.courier import serialization as ser

# ---- tunables (module-level so tests/benchmarks can shrink them) ------------

RING_CAPACITY = 1 << 20        # per-direction control-ring data bytes
SPILL_THRESHOLD = 96 * 1024    # messages above this go to the bulk slot
SLOT_HEADROOM = 1.5            # bulk slots are grown to msg_size * this
CONNECT_WAIT_S = 5.0           # how long a client waits for the listener
ACCEPT_WAIT_S = 5.0            # how long a client waits for HELLO
_POLL_ACCEPT_S = 0.01          # listener connect-dir poll interval

# ---- record kinds ------------------------------------------------------------

KIND_HELLO = 0
KIND_CALL = 1
KIND_BATCH = 2
KIND_REPLY = 3
KIND_BATCH_REPLY = 4
KIND_CLOSE = 5

_REC = struct.Struct("<IIQ")       # size (incl. header), kind, req_id
_SPILL_MAGIC = b"\xc5\x02"         # bulk-slot reference: namelen|name|total
_SPILL_HEAD = struct.Struct("<H")  # segment-name length
_SPILL_LEN = struct.Struct("<Q")   # framed-message length in the segment

# Segment header: wpos and rpos on separate cache lines; one closed byte
# per side so neither performs a read-modify-write on shared state.
_WPOS_OFF = 0
_RPOS_OFF = 64
_WCLOSED_OFF = 128
_RCLOSED_OFF = 129
_DATA_OFF = 192
_POS = struct.Struct("<Q")


class RingClosed(ConnectionError):
    """The peer closed its end of the ring (or went away)."""


class DecodeFailure:
    """A message that arrived intact but failed to unpickle; carries the
    decode exception while preserving reply correlation."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class ShmConnectError(ConnectionError):
    """Could not establish a shared-memory connection (caller may fall
    back to another transport)."""


def supported() -> bool:
    """Shared-memory transport is POSIX-only (named segments + pid probes)."""
    return os.name == "posix"


def _untrack(shm: shared_memory.SharedMemory) -> None:
    # Python <=3.12 registers every attach with the resource tracker, which
    # then unlinks segments owned by *other* processes at exit (bpo-39959).
    # We manage unlink ourselves, so take the segment out of the tracker.
    with contextlib.suppress(Exception):
        resource_tracker.unregister(shm._name, "shared_memory")  # noqa: SLF001


def _unlink_quiet(name: str) -> None:
    # shm_unlink without SharedMemory.unlink()'s resource-tracker
    # unregister (we already untracked; a second unregister raises in the
    # tracker daemon). ``name`` is the public segment name (no slash).
    try:
        import _posixshmem  # stdlib backend of shared_memory on POSIX
        with contextlib.suppress(FileNotFoundError):
            _posixshmem.shm_unlink("/" + name.lstrip("/"))
    except ImportError:  # pragma: no cover - non-POSIX
        with contextlib.suppress(Exception):
            shared_memory.SharedMemory(name=name).unlink()


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def _doorbell_wait(ready: Callable[[], bool], *,
                   deadline: Optional[float],
                   give_up: Callable[[], Optional[BaseException]]) -> bool:
    """Adaptive wait: yield-spin, then micro-sleeps capped at 500us.

    The hot phase uses ``time.sleep(0)`` (sched_yield), **never** a raw
    spin: a raw Python loop holds the GIL for a full switch interval
    (~5ms), convoying the very thread that would satisfy the wait when
    sender and waiter share a process. Yield-spinning keeps hot-path
    rendezvous in the tens of microseconds while costing idle waiters
    ~0 CPU once the sleep phase kicks in. Returns False on deadline;
    raises whatever ``give_up`` supplies (peer-closed / peer-dead
    detection, throttled — it may involve a pid-probe syscall)."""
    spins = 0
    while not ready():
        if spins % 128 == 0:
            exc = give_up()
            if exc is not None:
                raise exc
            if deadline is not None and time.monotonic() >= deadline:
                return False
        spins += 1
        if spins < 300:
            time.sleep(0)
        elif spins < 1500:
            time.sleep(0.00005)
        else:
            time.sleep(0.0005)
    return True


class Ring:
    """Single-producer single-consumer byte ring over one shm segment.

    Positions are monotonic u64s; the writer publishes ``wpos`` only after
    the record bytes are in place, the reader publishes ``rpos`` only after
    copying a record out, so each position has exactly one writer and the
    data path needs no cross-process lock. In-process concurrency (several
    client threads sending) is serialized by ``_wlock``.
    """

    def __init__(self, shm: shared_memory.SharedMemory, owner: bool):
        self._shm = shm
        self._buf = shm.buf
        self._cap = shm.size - _DATA_OFF
        self._owner = owner
        self._wlock = threading.Lock()
        self._released = False

    # -- construction --------------------------------------------------------
    @classmethod
    def create(cls, name: str, capacity: int = RING_CAPACITY) -> "Ring":
        shm = shared_memory.SharedMemory(name=name, create=True,
                                         size=capacity + _DATA_OFF)
        _untrack(shm)
        return cls(shm, owner=True)

    @classmethod
    def attach(cls, name: str) -> "Ring":
        shm = shared_memory.SharedMemory(name=name)
        _untrack(shm)
        return cls(shm, owner=False)

    @property
    def name(self) -> str:
        return self._shm.name

    # -- header accessors ----------------------------------------------------
    def _load(self, off: int) -> int:
        return _POS.unpack_from(self._buf, off)[0]

    def _store(self, off: int, value: int) -> None:
        _POS.pack_into(self._buf, off, value)

    def close_write(self) -> None:
        self._buf[_WCLOSED_OFF] = 1

    def close_read(self) -> None:
        self._buf[_RCLOSED_OFF] = 1

    @property
    def writer_closed(self) -> bool:
        return self._buf[_WCLOSED_OFF] != 0

    @property
    def reader_closed(self) -> bool:
        return self._buf[_RCLOSED_OFF] != 0

    def has_backlog(self) -> bool:
        """More records waiting? (reader-side heuristic; racy by nature)"""
        return self._load(_WPOS_OFF) != self._load(_RPOS_OFF)

    # -- data path -----------------------------------------------------------
    def write(self, kind: int, req_id: int, chunks,
              timeout: Optional[float] = None,
              give_up: Optional[Callable[[], Optional[BaseException]]] = None
              ) -> None:
        """Gather ``chunks`` into one contiguous record. Blocks while the
        ring is full; raises :class:`RingClosed` if the reader is gone."""
        views = [memoryview(c).cast("B") for c in chunks]
        total = _REC.size + sum(v.nbytes for v in views)
        if total > self._cap:
            raise ValueError(
                f"record of {total} bytes exceeds ring capacity {self._cap} "
                "(spill threshold misconfigured?)")
        deadline = None if timeout is None else time.monotonic() + timeout

        def _give_up():
            if self.reader_closed:
                return RingClosed("ring reader closed")
            return give_up() if give_up is not None else None

        with self._wlock:
            wpos = self._load(_WPOS_OFF)
            while True:
                off = wpos % self._cap
                rem = self._cap - off
                # Bytes needed *now*: the record, plus the tail bytes a pad
                # (or implicit skip) would consume first.
                need = rem + total if rem < total else total
                if not _doorbell_wait(
                        lambda: self._cap - (wpos - self._load(_RPOS_OFF))
                        >= need,
                        deadline=deadline, give_up=_give_up):
                    raise TimeoutError("ring full")
                if rem < _REC.size:
                    # Tail too small even for a header: both sides skip it.
                    wpos += rem
                    self._store(_WPOS_OFF, wpos)
                    continue
                if rem < total:
                    # Pad record: reader jumps to the wrap point.
                    _REC.pack_into(self._buf, _DATA_OFF + off, 0, 0, 0)
                    wpos += rem
                    self._store(_WPOS_OFF, wpos)
                    continue
                pos = _DATA_OFF + off
                _REC.pack_into(self._buf, pos, total, kind, req_id)
                pos += _REC.size
                for v in views:
                    ser.copy_into(self._buf, pos, v)
                    pos += v.nbytes
                # Publish *after* the payload is in place.
                self._store(_WPOS_OFF, wpos + total)
                return

    def read(self, timeout: Optional[float] = None,
             give_up: Optional[Callable[[], Optional[BaseException]]] = None
             ) -> Optional[tuple[int, int, bytes]]:
        """Pop one record as ``(kind, req_id, body)``; the body is copied
        out so ring space recycles immediately. ``None`` on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout

        def _give_up():
            if self.writer_closed and self._load(_WPOS_OFF) == rpos:
                return RingClosed("ring writer closed")
            return give_up() if give_up is not None else None

        rpos = self._load(_RPOS_OFF)
        while True:
            if not _doorbell_wait(lambda: self._load(_WPOS_OFF) != rpos,
                                  deadline=deadline, give_up=_give_up):
                return None
            off = rpos % self._cap
            rem = self._cap - off
            if rem < _REC.size:
                rpos += rem
                self._store(_RPOS_OFF, rpos)
                continue
            size, kind, req_id = _REC.unpack_from(self._buf, _DATA_OFF + off)
            if size == 0:  # pad
                rpos += rem
                self._store(_RPOS_OFF, rpos)
                continue
            start = _DATA_OFF + off + _REC.size
            body = ser.read_copy(self._buf, start, size - _REC.size)
            rpos += size
            self._store(_RPOS_OFF, rpos)
            return kind, req_id, body

    # -- lifecycle -----------------------------------------------------------
    def release(self, unlink: bool = False) -> None:
        """Drop our mapping (and the name, if ``unlink``). Idempotent."""
        if self._released:
            return
        self._released = True
        self._buf = None  # release the exported memoryview before close()
        name = self._shm.name
        with contextlib.suppress(Exception):
            self._shm.close()
        if unlink:
            _unlink_quiet(name)


class Slot:
    """One-message side segment for bulk payloads, written at a fixed
    offset (hot cache region, unlike cycling through a big ring).

    ``seq_written`` (writer-owned, at :data:`_WPOS_OFF`) and
    ``seq_consumed`` (reader-owned, at :data:`_RPOS_OFF`) implement a
    single-entry handshake: the writer waits until the previous message
    was copied out, fills the data region, publishes ``seq_written``, and
    only then emits the control-ring reference, so the reader never sees
    a half-written slot.
    """

    def __init__(self, shm: shared_memory.SharedMemory):
        self._shm = shm
        self._buf = shm.buf
        self.capacity = shm.size - _DATA_OFF
        self._released = False

    @classmethod
    def create(cls, name: str, capacity: int) -> "Slot":
        shm = shared_memory.SharedMemory(name=name, create=True,
                                         size=capacity + _DATA_OFF)
        _untrack(shm)
        return cls(shm)

    @classmethod
    def attach(cls, name: str) -> "Slot":
        shm = shared_memory.SharedMemory(name=name)
        _untrack(shm)
        return cls(shm)

    @property
    def name(self) -> str:
        return self._shm.name

    def _load(self, off: int) -> int:
        return _POS.unpack_from(self._buf, off)[0]

    @property
    def free(self) -> bool:
        return self._load(_WPOS_OFF) == self._load(_RPOS_OFF)

    def write_frames(self, frames, timeout: Optional[float] = None,
                     give_up: Optional[Callable] = None) -> None:
        """Wait for the slot to be free, then gather ``frames`` into it."""
        deadline = None if timeout is None else time.monotonic() + timeout

        def _give_up():
            if self._buf[_RCLOSED_OFF] != 0:
                return RingClosed("slot reader closed")
            return give_up() if give_up is not None else None

        if not _doorbell_wait(lambda: self.free, deadline=deadline,
                              give_up=_give_up):
            raise TimeoutError("bulk slot still in use")
        ser.write_framed_into(memoryview(self._buf)[_DATA_OFF:], frames)
        _POS.pack_into(self._buf, _WPOS_OFF, self._load(_WPOS_OFF) + 1)

    def unpublish(self) -> None:
        """Roll back the last ``write_frames`` (writer-side only, and only
        before its control-ring reference was emitted — the reader cannot
        have touched it). Keeps a failed send from poisoning the slot."""
        _POS.pack_into(self._buf, _WPOS_OFF, self._load(_WPOS_OFF) - 1)

    def consume(self, total: int) -> Any:
        """Copy the current message out, free the slot, decode."""
        data = ser.read_copy(self._buf, _DATA_OFF, total)
        _POS.pack_into(self._buf, _RPOS_OFF, self._load(_WPOS_OFF))
        return ser.loads(data)

    def close_read(self) -> None:
        self._buf[_RCLOSED_OFF] = 1

    def release(self, unlink: bool = False) -> None:
        if self._released:
            return
        self._released = True
        self._buf = None
        name = self._shm.name
        with contextlib.suppress(Exception):
            self._shm.close()
        if unlink:
            _unlink_quiet(name)


# ---- one direction: control ring + lazy bulk slot ---------------------------

class Chan:
    """One direction of a connection.

    Small messages gather straight into the control ring. Larger ones go
    through the direction's *bulk slot* (see :class:`Slot`) — created
    lazily by the writer, reused for the connection's lifetime, regrown
    under a fresh versioned name when a bigger message arrives. A tiny
    ``_SPILL_MAGIC`` reference (segment name + length) enters the control
    ring; the reader attaches the named slot (cached) and copies the
    message out. The per-direction send lock keeps slot fills and control
    records in lockstep order.
    """

    def __init__(self, ctrl: Ring, bulk_name: str, writer: bool):
        self._ctrl = ctrl
        self._bulk_name = bulk_name
        self._writer = writer
        self._slot: Optional[Slot] = None
        self._slot_version = 0
        self._slots_attached: dict[str, Slot] = {}
        self._lock = threading.Lock()

    # -- writer side ---------------------------------------------------------
    def _writer_slot(self, total: int, timeout, give_up) -> Slot:
        if self._slot is None or self._slot.capacity < total:
            if self._slot is not None:
                # All refs to the old slot were consumed (it is free by
                # the time we grow), so dropping the name is safe; the
                # reader's cached attachment stays valid until released.
                wait_s = 30.0 if timeout is None else timeout
                if not _doorbell_wait(lambda: self._slot.free,
                                      deadline=time.monotonic() + wait_s,
                                      give_up=give_up or (lambda: None)):
                    raise TimeoutError("bulk slot still in use")
                self._slot.release(unlink=True)
            self._slot_version += 1
            self._slot = Slot.create(
                f"{self._bulk_name}v{self._slot_version}",
                int(total * SLOT_HEADROOM))
        return self._slot

    def send(self, kind: int, req_id: int, obj: Any,
             timeout: Optional[float] = None, give_up=None) -> None:
        frames = ser.encode_frames(obj)
        total = ser.framed_size(frames)
        with self._lock:
            if total <= SPILL_THRESHOLD:
                self._ctrl.write(kind, req_id, ser.framed_chunks(frames),
                                 timeout=timeout, give_up=give_up)
                return
            slot = self._writer_slot(total, timeout, give_up)
            slot.write_frames(frames, timeout=timeout, give_up=give_up)
            name_b = slot.name.encode()
            ref = (_SPILL_MAGIC + _SPILL_HEAD.pack(len(name_b)) + name_b
                   + _SPILL_LEN.pack(total))
            try:
                self._ctrl.write(kind, req_id, [ref], timeout=timeout,
                                 give_up=give_up)
            except BaseException:
                # The reference never entered the ring: roll the slot
                # publish back so the next send doesn't wait forever on a
                # message nobody will ever consume.
                slot.unpublish()
                raise

    # -- reader side ---------------------------------------------------------
    def recv(self, timeout: Optional[float] = None, give_up=None
             ) -> Optional[tuple[int, int, Any]]:
        """Pop and decode one message. A payload that fails to decode
        (e.g. a class importable only on the peer) comes back as a
        :class:`DecodeFailure` so the request id is not lost — the caller
        can still correlate an error reply."""
        rec = self._ctrl.read(timeout=timeout, give_up=give_up)
        if rec is None:
            return None
        kind, req_id, body = rec
        try:
            obj = self._decode(req_id, body, give_up)
        except RingClosed:
            raise
        except BaseException as exc:  # noqa: BLE001
            obj = DecodeFailure(exc)
        return kind, req_id, obj

    def _decode(self, req_id: int, body: bytes, give_up) -> Any:
        if bytes(body[:2]) == _SPILL_MAGIC:
            (name_len,) = _SPILL_HEAD.unpack_from(body, 2)
            name = bytes(body[4:4 + name_len]).decode()
            (total,) = _SPILL_LEN.unpack_from(body, 4 + name_len)
            slot = self._slots_attached.get(name)
            if slot is None:
                slot = Slot.attach(name)
                self._slots_attached[name] = slot
            # The slot was filled and published before its control-ring
            # reference, so the message is already there.
            return slot.consume(total)
        return ser.loads(body)

    # -- lifecycle -----------------------------------------------------------
    def close_write(self) -> None:
        with contextlib.suppress(Exception):
            self._ctrl.close_write()

    def close_read(self) -> None:
        with contextlib.suppress(Exception):
            self._ctrl.close_read()
        for slot in self._slots_attached.values():
            with contextlib.suppress(Exception):
                slot.close_read()  # unblock a writer waiting on the slot

    @property
    def ctrl(self) -> Ring:
        return self._ctrl

    def release(self, unlink: bool = False) -> None:
        self._ctrl.release(unlink=unlink)
        if self._slot is not None:
            self._slot.release(unlink=True)  # writer owns the slot name
            self._slot = None
        for slot in self._slots_attached.values():
            slot.release()
        self._slots_attached.clear()


def _sweep_segments(prefix: str) -> None:
    """Best-effort unlink of leftover segments (crashed peer / unread
    spills). POSIX shm appears under /dev/shm on Linux."""
    for path in glob.glob(f"/dev/shm/{prefix}*"):
        with contextlib.suppress(Exception):
            _unlink_quiet(os.path.basename(path))


# ---- rendezvous --------------------------------------------------------------

def _root_dir() -> str:
    return os.path.join(tempfile.gettempdir(), "courier-shm")


def rendezvous_dir(name: str) -> str:
    return os.path.join(_root_dir(), name)


def probe(name: str) -> str:
    """Listener state: ``"ready"`` | ``"stale"`` (dead pid / wrong host /
    unreadable meta) | ``"absent"``."""
    meta_path = os.path.join(rendezvous_dir(name), "listener.json")
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except FileNotFoundError:
        return "absent"
    except Exception:
        return "stale"
    if meta.get("host") != socket.gethostname():
        return "stale"
    pid = meta.get("pid")
    if not isinstance(pid, int) or not _pid_alive(pid):
        return "stale"
    return "ready"


def cleanup(name: str) -> None:
    """Remove a service's rendezvous directory and leftover segments —
    used by launchers tearing down hard-killed nodes."""
    d = rendezvous_dir(name)
    with contextlib.suppress(Exception):
        for fn in os.listdir(d):
            with contextlib.suppress(OSError):
                os.unlink(os.path.join(d, fn))
        os.rmdir(d)


# ---- server side -------------------------------------------------------------

class _ServerConn:
    """One accepted client: a reader thread draining the request channel
    and a reply channel shared by the handler pool."""

    def __init__(self, listener: "ShmListener", conn_id: str,
                 req: Ring, rep: Ring, client_pid: int):
        self._listener = listener
        self._conn_id = conn_id
        self._in = Chan(req, bulk_name=f"{conn_id}qb", writer=False)
        self._out = Chan(rep, bulk_name=f"{conn_id}rb", writer=True)
        self._client_pid = client_pid
        self._thread = threading.Thread(
            target=self._serve, name=f"courier-shm-conn/{conn_id}",
            daemon=True)

    def start(self) -> None:
        self._out.ctrl.write(KIND_HELLO, 0, [b""])
        self._thread.start()

    def _client_gone(self) -> Optional[BaseException]:
        # Wakes reply writers blocked on a full ring whose client was
        # SIGKILLed (a dead client never sets its reader-closed flag).
        if not _pid_alive(self._client_pid):
            return RingClosed("client process died")
        return None

    def _reply(self, kind: int, req_id: int, obj: Any) -> None:
        try:
            self._out.send(kind, req_id, obj, give_up=self._client_gone)
        except RingClosed:
            pass  # client left; nothing to deliver the reply to
        except Exception:
            # Unpicklable result/exception: degrade per-status, exactly
            # like the gRPC path's encode_reply_error fallbacks.
            with contextlib.suppress(RingClosed):
                self._out.send(kind, req_id, _degrade(kind, obj),
                               give_up=self._client_gone)

    def _run_call(self, req_id: int, call: tuple) -> None:
        lst = self._listener
        try:
            # handler_init inside the try: its failure must become an
            # error reply, not a silently-dropped pool future that leaves
            # the client waiting forever.
            if lst.handler_init is not None:
                lst.handler_init()
            method, args, kwargs = call
            status = ser.make_ok_status(lst.invoke(method, args, kwargs))
        except BaseException as exc:  # noqa: BLE001 - ship any failure back
            status = ser.make_error_status(exc)
        self._reply(KIND_REPLY, req_id, status)

    def _run_batch(self, req_id: int, calls: list) -> None:
        lst = self._listener
        try:
            if lst.handler_init is not None:
                lst.handler_init()
        except BaseException as exc:  # noqa: BLE001 - whole-batch failure
            self._reply(KIND_REPLY, req_id, ser.make_error_status(exc))
            return
        statuses = []
        for method, args, kwargs in calls:
            # Per-call isolation, statuses in request order (same contract
            # as /courier/BatchCall).
            try:
                statuses.append(
                    ser.make_ok_status(lst.invoke(method, args, kwargs)))
            except BaseException as exc:  # noqa: BLE001
                statuses.append(ser.make_error_status(exc))
        self._reply(KIND_BATCH_REPLY, req_id, statuses)

    def _serve(self) -> None:
        try:
            while not self._listener.stopped:
                try:
                    # Decode happens here (slot consumption must follow
                    # control-ring order); only the invoke may run pooled.
                    rec = self._in.recv(timeout=0.2)
                except RingClosed:
                    return
                if rec is None:
                    if not _pid_alive(self._client_pid):
                        return  # client died without a CLOSE
                    continue
                kind, req_id, obj = rec
                if kind == KIND_CLOSE:
                    return
                if isinstance(obj, DecodeFailure):
                    self._reply(KIND_REPLY, req_id,
                                ser.make_error_status(obj.exc))
                    continue
                if kind == KIND_CALL:
                    runner = self._run_call
                elif kind == KIND_BATCH:
                    runner = self._run_batch
                else:
                    continue
                # A lone request runs inline: on small hosts a pool
                # hand-off costs a wake AND leaves this thread spinning
                # next to the worker. A client with pipelined backlog
                # keeps pool concurrency (its calls must not serialize
                # behind one long handler). Caveat: a handler that blocks
                # until a *later* request from the same client arrives
                # can stall its own connection — don't write services
                # like that (other clients' connections are unaffected).
                if self._in.ctrl.has_backlog():
                    try:
                        self._listener.pool.submit(runner, req_id, obj)
                    except RuntimeError:
                        return  # listener stopped the pool mid-accept
                else:
                    runner(req_id, obj)
        finally:
            self._out.close_write()
            self._in.close_read()
            self._in.release()
            self._out.release()
            _sweep_segments(f"{self._conn_id}")
            self._listener.forget(self)


def _degrade(kind: int, obj: Any) -> Any:
    """Build a picklable stand-in for a reply that failed to encode."""
    def one(status):
        try:
            ser.encode_frames(status)
            return status
        except Exception:
            if status[0] == "ok":
                return ("err", ser.RemoteError(
                    f"result of type {type(status[1]).__name__} is not "
                    "serializable"), "")
            return ("err", ser.RemoteError(repr(status[1])), status[2])
    if kind == KIND_BATCH_REPLY:
        return [one(s) for s in obj]
    return one(obj)


class ShmListener:
    """Accepts shm connections for one service name, alongside whatever
    other transports the server runs. ``invoke`` is the server's dispatch
    (method, args, kwargs) -> value; ``handler_init`` runs at the top of
    every request on the handling thread (same contract as CourierServer).
    """

    def __init__(self, name: str, invoke: Callable[[str, tuple, dict], Any],
                 handler_init: Optional[Callable[[], None]] = None,
                 max_workers: int = 16):
        if not supported():  # pragma: no cover - POSIX-only guard
            raise ShmConnectError("shm transport requires POSIX")
        self.name = name
        self.invoke = invoke
        self.handler_init = handler_init
        self.stopped = False
        self.pool = futures.ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="courier-shm-srv")
        self._dir = rendezvous_dir(name)
        self._conns: list[_ServerConn] = []
        self._conns_lock = threading.Lock()
        self._accept_thread: Optional[threading.Thread] = None
        os.makedirs(self._dir, exist_ok=True)
        meta = {"host": socket.gethostname(), "pid": os.getpid(),
                "version": 1}
        tmp = os.path.join(self._dir, f".meta.{os.getpid()}.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(self._dir, "listener.json"))

    @property
    def endpoint(self) -> str:
        return f"shm://{self.name}"

    def start(self) -> None:
        if self._accept_thread is not None:
            return
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"courier-shm-accept/{self.name}",
            daemon=True)
        self._accept_thread.start()

    def _accept_one(self, path: str) -> None:
        try:
            with open(path) as f:
                req = json.load(f)
            os.unlink(path)
            conn = _ServerConn(self, req["conn"],
                               req=Ring.attach(req["req"]),
                               rep=Ring.attach(req["rep"]),
                               client_pid=int(req["pid"]))
        except Exception:  # malformed/raced connect file: drop it
            with contextlib.suppress(OSError):
                os.unlink(path)
            return
        with self._conns_lock:
            self._conns.append(conn)
        conn.start()

    def _accept_loop(self) -> None:
        while not self.stopped:
            try:
                pending = sorted(
                    fn for fn in os.listdir(self._dir)
                    if fn.endswith(".connect"))
            except FileNotFoundError:
                return  # rendezvous dir removed under us: stop accepting
            for fn in pending:
                self._accept_one(os.path.join(self._dir, fn))
            time.sleep(_POLL_ACCEPT_S)

    def forget(self, conn: _ServerConn) -> None:
        with self._conns_lock:
            if conn in self._conns:
                self._conns.remove(conn)

    def stop(self) -> None:
        if self.stopped:
            return
        self.stopped = True
        cleanup(self.name)  # unadvertise first: no new connects
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            # Wake blocked clients; the conn thread may be releasing the
            # ring concurrently, which is fine — the client also watches
            # our pid.
            conn._out.close_write()  # noqa: SLF001
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=2.0)
        self.pool.shutdown(wait=False)


# ---- client side -------------------------------------------------------------

class ClientConnection:
    """The client half of one shm connection: creates the rings, performs
    the rendezvous handshake, then sends records / receives replies."""

    def __init__(self, name: str, req: Ring, rep: Ring, conn_id: str,
                 server_pid: int):
        self.name = name
        self._out = Chan(req, bulk_name=f"{conn_id}qb", writer=True)
        self._in = Chan(rep, bulk_name=f"{conn_id}rb", writer=False)
        self._conn_id = conn_id
        self._server_pid = server_pid
        self._closed = False

    @classmethod
    def connect(cls, name: str, wait: Optional[float] = None
                ) -> "ClientConnection":
        if not supported():
            raise ShmConnectError("shm transport requires POSIX")
        wait = CONNECT_WAIT_S if wait is None else wait
        deadline = time.monotonic() + wait
        # Wait for the listener to advertise (launch is asynchronous); a
        # stale advertisement (dead pid) fails immediately so callers can
        # fall back instead of hanging on a crashed server's leftovers.
        while True:
            state = probe(name)
            if state == "ready":
                break
            if state == "stale":
                raise ShmConnectError(
                    f"shm listener for {name!r} is stale (server crashed?)")
            if time.monotonic() >= deadline:
                raise ShmConnectError(
                    f"shm listener for {name!r} did not come up within "
                    f"{wait:.1f}s")
            time.sleep(0.005)
        d = rendezvous_dir(name)
        try:
            with open(os.path.join(d, "listener.json")) as f:
                server_pid = int(json.load(f)["pid"])
        except (OSError, ValueError, KeyError) as exc:
            # The listener can unadvertise between probe() and this read;
            # surface it as a connect failure so callers fall back.
            raise ShmConnectError(
                f"shm listener for {name!r} disappeared during connect: "
                f"{exc!r}") from exc
        conn_id = f"cur{os.getpid():x}{uuid.uuid4().hex[:8]}"
        req = Ring.create(f"{conn_id}q")
        rep = Ring.create(f"{conn_id}r")
        try:
            spec = {"conn": conn_id, "req": req.name, "rep": rep.name,
                    "pid": os.getpid()}
            tmp = os.path.join(d, f".{conn_id}.tmp")
            with open(tmp, "w") as f:
                json.dump(spec, f)
            os.replace(tmp, os.path.join(d, f"{conn_id}.connect"))
            # The HELLO record doubles as the accept ack.
            def _server_died():
                if not _pid_alive(server_pid):
                    return ShmConnectError(
                        f"shm listener for {name!r} died during handshake")
                return None
            rec = rep.read(timeout=ACCEPT_WAIT_S, give_up=_server_died)
            if rec is None or rec[0] != KIND_HELLO:
                raise ShmConnectError(
                    f"shm listener for {name!r} did not accept within "
                    f"{ACCEPT_WAIT_S:.1f}s")
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(os.path.join(d, f"{conn_id}.connect"))
            req.release(unlink=True)
            rep.release(unlink=True)
            raise
        return cls(name, req, rep, conn_id, server_pid)

    # -- data path -----------------------------------------------------------
    def send(self, kind: int, req_id: int, obj: Any,
             timeout: Optional[float] = None) -> None:
        def _server_died():
            if not _pid_alive(self._server_pid):
                return RingClosed("server process died")
            return None
        self._out.send(kind, req_id, obj, timeout=timeout,
                       give_up=_server_died)

    def recv(self, timeout: Optional[float] = None
             ) -> Optional[tuple[int, int, Any]]:
        return self._in.recv(timeout=timeout)

    def peer_alive(self) -> bool:
        return _pid_alive(self._server_pid) and not self._in.ctrl.writer_closed

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        with contextlib.suppress(Exception):
            self._out.ctrl.write(KIND_CLOSE, 0, [b""], timeout=0.2)
        self._out.close_write()
        self._in.close_read()

    def release(self) -> None:
        """Unlink the rings (the client created both control rings) plus
        any bulk/one-off segments left under this connection's prefix."""
        self._out.release(unlink=True)
        self._in.release(unlink=True)
        _sweep_segments(self._conn_id)
