"""In-process courier channel (shared-memory fast path).

Paper §4: "the Handle abstraction ... allows us to flexibly choose the most
appropriate client type at launch phase (e.g., to use a shared-memory
channel if the service is allocated on the same physical machine)."

The thread launcher and ColocationNode resolve addresses to
``inproc://<name>`` endpoints backed by this registry. Calls are direct
method invocations (zero serialization), with ``.futures`` served from a
shared thread pool, so the API is identical to the gRPC client.
"""

from __future__ import annotations

import threading
from concurrent import futures
from typing import Any, Optional

_registry: dict[str, Any] = {}
_registry_lock = threading.Lock()
_pool: Optional[futures.ThreadPoolExecutor] = None
_pool_lock = threading.Lock()


def _shared_pool() -> futures.ThreadPoolExecutor:
    global _pool
    with _pool_lock:
        if _pool is None:
            _pool = futures.ThreadPoolExecutor(
                max_workers=64, thread_name_prefix="courier-inproc")
        return _pool


def register(name: str, obj: Any) -> None:
    with _registry_lock:
        if name in _registry:
            raise RuntimeError(f"inproc service {name!r} already registered")
        _registry[name] = obj


def unregister(name: str) -> None:
    with _registry_lock:
        _registry.pop(name, None)


def lookup(name: str, timeout_s: float = 10.0) -> Any:
    """Resolve a service, waiting for it to come up (launch is async:
    a client node may start before its server node has registered)."""
    import time
    deadline = time.monotonic() + timeout_s
    while True:
        with _registry_lock:
            if name in _registry:
                return _registry[name]
            known = sorted(_registry)
        if time.monotonic() >= deadline:
            raise RuntimeError(
                f"inproc service {name!r} did not come up within "
                f"{timeout_s}s (registered: {known})")
        time.sleep(0.005)


def reset() -> None:
    """Test hook: clear all registered in-process services."""
    with _registry_lock:
        _registry.clear()


class _FuturesProxy:
    def __init__(self, obj: Any):
        self._obj = obj

    def __getattr__(self, method: str):
        fn = getattr(self._obj, method)
        pool = _shared_pool()

        def call(*args, **kwargs):
            return pool.submit(fn, *args, **kwargs)

        return call


class InProcessClient:
    """Courier client for a same-process service: direct calls + .futures."""

    def __init__(self, name: str):
        self._name = name
        self._obj = None

    def _target(self) -> Any:
        if self._obj is None:
            self._obj = lookup(self._name)
        return self._obj

    @property
    def futures(self) -> _FuturesProxy:
        return _FuturesProxy(self._target())

    def __getattr__(self, method: str):
        if method.startswith("_"):
            raise AttributeError(method)
        return getattr(self._target(), method)

    def __repr__(self) -> str:
        return f"InProcessClient({self._name!r})"
