"""In-process service registry (shared-memory fast path).

Paper §4: "the Handle abstraction ... allows us to flexibly choose the most
appropriate client type at launch phase (e.g., to use a shared-memory
channel if the service is allocated on the same physical machine)."

The thread launcher and ColocationNode resolve addresses to
``inproc://<name>`` endpoints backed by this registry. The client side
lives in :class:`repro.core.courier.transport.InProcTransport` (behind the
unified ``CourierClient``); this module only owns the name -> object map
and the shared thread pool that serves ``.futures`` calls.
"""

from __future__ import annotations

import threading
import time
from concurrent import futures
from typing import Any, Optional

_registry: dict[str, Any] = {}
_registry_lock = threading.Lock()
_pool: Optional[futures.ThreadPoolExecutor] = None
_pool_lock = threading.Lock()


def shared_pool() -> futures.ThreadPoolExecutor:
    global _pool
    with _pool_lock:
        if _pool is None:
            # Sized for blocking-handler services (a serve fabric holds one
            # handler thread per in-flight request): a full pool makes a
            # dispatched call wait behind workers blocked on *other*
            # services, which starves an idle replica while its siblings
            # queue. Workers spawn lazily, so the ceiling is cheap.
            _pool = futures.ThreadPoolExecutor(
                max_workers=256, thread_name_prefix="courier-inproc")
        return _pool


def register(name: str, obj: Any) -> None:
    with _registry_lock:
        if name in _registry:
            raise RuntimeError(f"inproc service {name!r} already registered")
        _registry[name] = obj


def unregister(name: str) -> None:
    with _registry_lock:
        _registry.pop(name, None)


def lookup(name: str, timeout_s: float = 10.0) -> Any:
    """Resolve a service, waiting for it to come up (launch is async:
    a client node may start before its server node has registered)."""
    deadline = time.monotonic() + timeout_s
    while True:
        with _registry_lock:
            if name in _registry:
                return _registry[name]
            known = sorted(_registry)
        if time.monotonic() >= deadline:
            raise RuntimeError(
                f"inproc service {name!r} did not come up within "
                f"{timeout_s}s (registered: {known})")
        time.sleep(0.005)


def reset() -> None:
    """Test hook: clear all registered in-process services."""
    with _registry_lock:
        _registry.clear()


def InProcessClient(name: str):
    """Back-compat constructor: the unified client over InProcTransport."""
    from repro.core.courier.client import CourierClient
    return CourierClient(f"inproc://{name}")
