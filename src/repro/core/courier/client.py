"""Courier client: RPC proxy whose attributes are remote methods (paper §4.1).

"from the perspective of any consuming class remote communication is
invisible and it appears as if it is just using the original Python
objects." Also exposes ``client.futures.method(...)`` returning a
concurrent.futures.Future (used by the ES example, §5.3).
"""

from __future__ import annotations

import threading
from concurrent import futures as cf
from typing import Any, Optional

import grpc

from repro.core.courier import serialization as ser
from repro.core.courier.server import COURIER_METHOD

_GRPC_OPTIONS = [
    ("grpc.max_send_message_length", -1),
    ("grpc.max_receive_message_length", -1),
]


class _GrpcFuture(cf.Future):
    """Adapts a grpc future into a concurrent.futures.Future."""

    @classmethod
    def wrap(cls, grpc_future) -> "cf.Future":
        out = cls()
        out.set_running_or_notify_cancel()

        def _done(gf):
            try:
                out.set_result(ser.decode_reply(gf.result()))
            except BaseException as exc:  # noqa: BLE001
                out.set_exception(exc)

        grpc_future.add_done_callback(_done)
        return out


class _FuturesProxy:
    def __init__(self, client: "CourierClient"):
        self._client = client

    def __getattr__(self, method: str):
        def call(*args, **kwargs) -> cf.Future:
            payload = ser.encode_call(method, args, kwargs)
            gf = self._client._callable.future(
                payload, timeout=self._client._timeout,
                wait_for_ready=True)
            return _GrpcFuture.wrap(gf)

        return call


class CourierClient:
    """Client for a courier endpoint (``grpc://host:port``)."""

    def __init__(self, endpoint: str, timeout: Optional[float] = None):
        if endpoint.startswith("grpc://"):
            endpoint = endpoint[len("grpc://"):]
        self._endpoint = endpoint
        self._timeout = timeout
        self._lock = threading.Lock()
        self._channel: Optional[grpc.Channel] = None
        self.__callable = None

    @property
    def _callable(self):
        with self._lock:
            if self.__callable is None:
                self._channel = grpc.insecure_channel(
                    self._endpoint, options=_GRPC_OPTIONS)
                self.__callable = self._channel.unary_unary(
                    COURIER_METHOD,
                    request_serializer=None,
                    response_deserializer=None)
            return self.__callable

    @property
    def futures(self) -> _FuturesProxy:
        return _FuturesProxy(self)

    def __getattr__(self, method: str):
        if method.startswith("_") or method in ("futures",):
            raise AttributeError(method)

        def call(*args, **kwargs):
            payload = ser.encode_call(method, args, kwargs)
            # wait_for_ready: don't fail calls issued before the server
            # node finished binding (launch is asynchronous).
            reply = self._callable(payload, timeout=self._timeout,
                                   wait_for_ready=True)
            return ser.decode_reply(reply)

        return call

    def close(self) -> None:
        with self._lock:
            if self._channel is not None:
                self._channel.close()
                self._channel = None
                self.__callable = None

    def __repr__(self) -> str:
        return f"CourierClient(grpc://{self._endpoint})"
