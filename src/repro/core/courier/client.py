"""Unified courier client: RPC proxy whose attributes are remote methods
(paper §4.1).

"from the perspective of any consuming class remote communication is
invisible and it appears as if it is just using the original Python
objects." One client class serves every transport — gRPC or in-process —
so the futures-proxy and method-proxy logic lives in exactly one place.

API surface::

    client = CourierClient("grpc://host:port")        # or "inproc://name"
    client.method(*args, **kwargs)                    # blocking call
    client.futures.method(*args, **kwargs)            # -> concurrent Future
    client.batch_call([(m, args, kwargs), ...])       # N calls, one frame
    client.futures.batch_call([...])                  # async batch
    with CourierClient(ep) as c: ...                  # scoped channel use

Results that contain arrays may be zero-copy: over the shm transport a
large reply's arrays are read-only views aliasing a shared-memory slot,
pinned by a lease that returns the slot to the sender's pool when the
result is garbage-collected. Drop results promptly, or detach them with
``courier.materialize(result)`` before retaining them long-term (a
handful of long-lived results otherwise starves the server's reply
pool). See courier/README.md, "The lease free protocol".
"""

from __future__ import annotations

from concurrent import futures as cf
from typing import Any, Optional, Sequence

from repro.core import telemetry
from repro.core.courier import serialization as ser
from repro.core.courier.transport import Call, Transport, make_transport


def _inject_calls(calls: Sequence[Call]) -> Sequence[Call]:
    """Fold the current sampled trace context into each batched call's
    kwargs (copy-on-write: caller-owned tuples are never mutated)."""
    if telemetry.current_context() is None:
        return calls
    return [(m, a, telemetry.inject(kw)) for m, a, kw in calls]


def _statuses_to_results(statuses: Sequence[tuple]) -> list:
    """Unwrap batch statuses; error slots hold the exception instance."""
    return [status[1] if status[0] == "ok"
            else ser.status_to_exception(status)
            for status in statuses]


class _FuturesProxy:
    """``client.futures.method(...)`` -> concurrent.futures.Future."""

    def __init__(self, transport: Transport):
        self._transport = transport

    def batch_call(self, calls: Sequence[Call]) -> cf.Future:
        """Async batch; resolves to per-call results in request order, with
        exception instances occupying the slots of failed calls."""
        inner = self._transport.batch_call_future(_inject_calls(calls))
        out: cf.Future = cf.Future()
        out.set_running_or_notify_cancel()

        def _done(f):
            try:
                out.set_result(_statuses_to_results(f.result()))
            except BaseException as exc:  # noqa: BLE001
                out.set_exception(exc)

        inner.add_done_callback(_done)
        return out

    def __getattr__(self, method: str):
        if method.startswith("_"):
            raise AttributeError(method)
        transport = self._transport

        def call(*args, **kwargs) -> cf.Future:
            return transport.call_future(method, args,
                                         telemetry.inject(kwargs))

        return call


class CourierClient:
    """Client for a courier endpoint, over whichever transport fits it.

    ``grpc://host:port`` -> :class:`GrpcTransport` (pooled channel, framed
    zero-copy wire format); ``shm://name`` -> :class:`ShmTransport`
    (same-host shared-memory rings); ``inproc://name`` ->
    :class:`InProcTransport` (direct invocation). A ``+``-joined endpoint
    (e.g. ``shm://n+grpc://h:p`` from the process launcher) tries the
    candidates in order — shm when a healthy same-host listener exists,
    gRPC otherwise. Close (or use as a context manager) to release the
    pooled channel / rings; double-close is a no-op.
    """

    def __init__(self, endpoint: str, timeout: Optional[float] = None,
                 wire_format: str = "frames",
                 transport: Optional[Transport] = None):
        self._transport = transport if transport is not None else \
            make_transport(endpoint, timeout=timeout, wire_format=wire_format)

    @property
    def endpoint(self) -> str:
        return self._transport.endpoint

    @property
    def transport(self) -> Transport:
        return self._transport

    @property
    def futures(self) -> _FuturesProxy:
        return _FuturesProxy(self._transport)

    def __getattr__(self, method: str):
        if method.startswith("_"):
            raise AttributeError(method)
        transport = self._transport

        def call(*args, **kwargs):
            return transport.call(method, args, telemetry.inject(kwargs))

        return call

    # -- batched RPC ---------------------------------------------------------
    def batch_call(self, calls: Sequence[Call],
                   return_exceptions: bool = False) -> list[Any]:
        """Execute ``calls`` — ``(method, args, kwargs)`` tuples — in one
        round trip.

        Results come back in request order. A failing call never aborts its
        siblings server-side; client-side, the first error is raised unless
        ``return_exceptions`` is set, in which case error slots hold the
        exception instance instead.
        """
        statuses = self._transport.batch_call(_inject_calls(calls))
        if return_exceptions:
            return _statuses_to_results(statuses)
        return [ser.status_to_result(status) for status in statuses]

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        self._transport.close()

    def __enter__(self) -> "CourierClient":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    def __repr__(self) -> str:
        return f"CourierClient({self.endpoint})"
