"""Courier server: expose an arbitrary Python object over gRPC (paper §4.1).

We register *generic* unary-unary handlers at ``/courier/Call`` and
``/courier/BatchCall`` so no protoc-generated stubs are needed. Requests
are framed ``(method, args, kwargs)`` messages (serialization.py); replies
are ``("ok", value)`` or ``("err", exc, traceback)`` statuses — a batch
request carries N calls in one frame and gets N statuses back, in order.
The server mirrors the request's wire format (framed vs. legacy bare
pickle), so old-format clients keep working.

Paper semantics implemented here:
  * all *public* methods of the wrapped object are exposed, except ``run``;
  * if a ``run`` method exists the worker executes it, otherwise the worker
    waits for incoming RPCs.
"""

from __future__ import annotations

import threading
from concurrent import futures
from typing import Any, Callable, Optional

import grpc

from repro.core import telemetry
from repro.core.courier import serialization as ser
from repro.core.courier import shm as shm_mod
from repro.core.courier.transport import (COURIER_BATCH_METHOD,
                                          COURIER_METHOD, _GRPC_OPTIONS)


class _GenericCourierHandler(grpc.GenericRpcHandler):
    def __init__(self, handlers: dict[str, Callable]):
        self._handlers = {
            method: grpc.unary_unary_rpc_method_handler(
                fn,
                request_deserializer=None,   # raw bytes in
                response_serializer=None,    # raw bytes out
            )
            for method, fn in handlers.items()
        }

    def service(self, handler_call_details):
        return self._handlers.get(handler_call_details.method)


class CourierServer:
    """Serves the public methods of ``obj`` at a gRPC endpoint.

    ``handler_init`` (optional) runs at the top of every RPC on the
    handling thread — launchers use it to install the node's
    :class:`WorkerContext` so service code can call ``lp.stop_program()``
    from inside an RPC handler.

    ``shm_name`` (optional) additionally serves same-host clients over a
    shared-memory ring listener (``shm://<shm_name>``) alongside the gRPC
    port — same dispatch, same exposure rules, same per-call batch
    isolation; the process launcher emits dual endpoints so same-host
    peers take the ring and everyone else falls back to gRPC.

    Request dispatch is zero-copy on both transports: decoded argument
    arrays are read-only views aliasing the inbound message (gRPC request
    bytes, or a shared-memory pool slot pinned by a lease). The lease is
    released after the handler returns — via refcount, so a handler that
    *retains* an argument array keeps the slot pinned and must
    ``np.copy`` it instead (see courier/README.md).
    """

    def __init__(self, obj: Any, port: int = 0, host: str = "127.0.0.1",
                 max_workers: int = 16,
                 handler_init: Optional[Callable[[], None]] = None,
                 shm_name: Optional[str] = None):
        self._obj = obj
        self._handler_init = handler_init
        self._lock = threading.Lock()  # guards lifecycle transitions
        self._server = grpc.server(
            futures.ThreadPoolExecutor(max_workers=max_workers,
                                       thread_name_prefix="courier-srv"),
            options=_GRPC_OPTIONS)
        self._server.add_generic_rpc_handlers(
            (_GenericCourierHandler({
                COURIER_METHOD: self._handle,
                COURIER_BATCH_METHOD: self._handle_batch,
            }),))
        self._port = self._server.add_insecure_port(f"{host}:{port}")
        if self._port == 0:
            raise RuntimeError(f"failed to bind courier server on {host}:{port}")
        self._host = host
        self._shm_name = shm_name
        self._shm_listener: Optional[shm_mod.ShmListener] = None
        self._max_workers = max_workers
        self._started = False
        self._stopped = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        with self._lock:
            if self._stopped:
                raise RuntimeError("CourierServer cannot restart after stop()")
            if self._started:
                return
            self._server.start()
            if self._shm_name is not None and shm_mod.supported():
                # Advertise the ring listener only once we actually serve.
                self._shm_listener = shm_mod.ShmListener(
                    self._shm_name, invoke=self._invoke,
                    handler_init=self._handler_init,
                    max_workers=self._max_workers)
                self._shm_listener.start()
            self._started = True

    def stop(self, grace: Optional[float] = 0.5) -> None:
        """Stop serving. Safe to call repeatedly or before start() (which
        releases the port bound in __init__ either way)."""
        with self._lock:
            if self._stopped:
                return
            self._stopped = True
            listener = self._shm_listener
            self._shm_listener = None
        if listener is not None:
            listener.stop()
        self._server.stop(grace)

    def wait(self) -> None:
        self._server.wait_for_termination()

    def __enter__(self) -> "CourierServer":
        self.start()
        return self

    def __exit__(self, *exc_info) -> None:
        self.stop()

    @property
    def endpoint(self) -> str:
        return f"grpc://{self._host}:{self._port}"

    @property
    def shm_endpoint(self) -> Optional[str]:
        return f"shm://{self._shm_name}" if self._shm_name else None

    @property
    def port(self) -> int:
        return self._port

    # -- request handling ----------------------------------------------------
    def _invoke(self, method: str, args: tuple, kwargs: dict) -> Any:
        if method.startswith("_") or method == "run":
            raise AttributeError(
                f"method {method!r} is not exposed over courier")
        # Trace envelope: the client-side proxy injected the sampled
        # context into kwargs; activate it on this handler thread so
        # spans recorded by the service nest under the caller's span.
        # This chokepoint covers gRPC unary, gRPC batch entries, and
        # the shm listener (which dispatches through invoke=).
        ctx = telemetry.extract(kwargs)
        if ctx is None:
            return getattr(self._obj, method)(*args, **kwargs)
        with telemetry.activate(ctx):
            return getattr(self._obj, method)(*args, **kwargs)

    def _handle(self, request: bytes, context) -> bytes:
        legacy = not ser.is_framed(request)
        if self._handler_init is not None:
            self._handler_init()
        try:
            method, args, kwargs = ser.decode_call(request)
            # Peek (don't pop — _invoke owns extraction) so the reply
            # serialization span lands in the same trace.
            wire = kwargs.get(telemetry.TRACE_KEY) \
                if isinstance(kwargs, dict) else None
            result = self._invoke(method, args, kwargs)
            ctx = telemetry.TraceContext.from_wire(wire) if wire else None
            if ctx is None:
                return ser.encode_reply_ok(result, legacy=legacy)
            with telemetry.activate(ctx):
                with telemetry.span("reply", method=method):
                    return ser.encode_reply_ok(result, legacy=legacy)
        except BaseException as exc:  # noqa: BLE001 - ship any failure back
            return ser.encode_reply_error(exc, legacy=legacy)

    def _handle_batch(self, request: bytes, context) -> bytes:
        legacy = not ser.is_framed(request)
        if self._handler_init is not None:
            self._handler_init()
        statuses = []
        try:
            calls = ser.decode_batch_call(request)
        except BaseException as exc:  # noqa: BLE001 - undecodable batch
            return ser.encode_reply_error(exc, legacy=legacy)
        for method, args, kwargs in calls:
            # Per-call isolation: one failing entry never aborts siblings,
            # and statuses come back in request order.
            try:
                statuses.append(
                    ser.make_ok_status(self._invoke(method, args, kwargs)))
            except BaseException as exc:  # noqa: BLE001
                statuses.append(ser.make_error_status(exc))
        return ser.encode_batch_reply(statuses, legacy=legacy)
