"""Courier server: expose an arbitrary Python object over gRPC (paper §4.1).

We register a *generic* unary-unary handler at ``/courier/Call`` so no
protoc-generated stubs are needed. Requests are
``cloudpickle((method, args, kwargs))``; replies are ``("ok", value)`` or
``("err", exc, traceback)``.

Paper semantics implemented here:
  * all *public* methods of the wrapped object are exposed, except ``run``;
  * if a ``run`` method exists the worker executes it, otherwise the worker
    waits for incoming RPCs.
"""

from __future__ import annotations

import threading
from concurrent import futures
from typing import Any, Optional

import grpc

from repro.core.courier import serialization as ser

_GRPC_OPTIONS = [
    ("grpc.max_send_message_length", -1),
    ("grpc.max_receive_message_length", -1),
]

COURIER_METHOD = "/courier/Call"


class _GenericCourierHandler(grpc.GenericRpcHandler):
    def __init__(self, handler):
        self._handler = grpc.unary_unary_rpc_method_handler(
            handler,
            request_deserializer=None,   # raw bytes in
            response_serializer=None,    # raw bytes out
        )

    def service(self, handler_call_details):
        if handler_call_details.method == COURIER_METHOD:
            return self._handler
        return None


class CourierServer:
    """Serves the public methods of ``obj`` at a gRPC endpoint."""

    def __init__(self, obj: Any, port: int = 0, host: str = "127.0.0.1",
                 max_workers: int = 16):
        self._obj = obj
        self._lock = threading.Lock()  # guards lazy method lookup only
        self._server = grpc.server(
            futures.ThreadPoolExecutor(max_workers=max_workers,
                                       thread_name_prefix="courier-srv"),
            options=_GRPC_OPTIONS)
        self._server.add_generic_rpc_handlers(
            (_GenericCourierHandler(self._handle),))
        self._port = self._server.add_insecure_port(f"{host}:{port}")
        if self._port == 0:
            raise RuntimeError(f"failed to bind courier server on {host}:{port}")
        self._host = host
        self._started = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        self._server.start()
        self._started = True

    def stop(self, grace: Optional[float] = 0.5) -> None:
        if self._started:
            self._server.stop(grace)
            self._started = False

    def wait(self) -> None:
        self._server.wait_for_termination()

    @property
    def endpoint(self) -> str:
        return f"grpc://{self._host}:{self._port}"

    @property
    def port(self) -> int:
        return self._port

    # -- request handling -----------------------------------------------------
    def _handle(self, request: bytes, context) -> bytes:
        try:
            method, args, kwargs = ser.decode_call(request)
            if method.startswith("_") or method == "run":
                raise AttributeError(
                    f"method {method!r} is not exposed over courier")
            fn = getattr(self._obj, method)
            return ser.encode_reply_ok(fn(*args, **kwargs))
        except BaseException as exc:  # noqa: BLE001 - ship any failure back
            return ser.encode_reply_error(exc)
