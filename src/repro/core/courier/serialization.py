"""Courier wire format.

cloudpickle (protocol 5) for arbitrary Python callables/classes — the paper
notes CourierNode "serializes the class and any given argument, which are
then shipped over network and deserialized at execution time". JAX arrays
are converted to numpy before pickling (device buffers don't transport);
they come back as numpy and re-device-put lazily on use.
"""

from __future__ import annotations

import io
import pickle
import traceback
from typing import Any

import cloudpickle
import numpy as np


def _to_transportable(obj: Any) -> Any:
    """Recursively convert jax.Array leaves to numpy (cheap on CPU)."""
    try:
        import jax
    except Exception:  # pragma: no cover - jax is a hard dep in this repo
        return obj
    if isinstance(obj, jax.Array):
        return np.asarray(obj)
    if isinstance(obj, (list, tuple)):
        conv = [_to_transportable(v) for v in obj]
        return tuple(conv) if isinstance(obj, tuple) else conv
    if isinstance(obj, dict):
        return {k: _to_transportable(v) for k, v in obj.items()}
    return obj


def dumps(obj: Any) -> bytes:
    return cloudpickle.dumps(_to_transportable(obj), protocol=5)


def loads(data: bytes) -> Any:
    return pickle.loads(data)


class RemoteError(RuntimeError):
    """An exception raised inside a remote service, re-raised client-side."""


# ---- call / reply framing ---------------------------------------------------

def encode_call(method: str, args: tuple, kwargs: dict) -> bytes:
    return dumps((method, args, kwargs))


def decode_call(data: bytes) -> tuple[str, tuple, dict]:
    return loads(data)


def encode_reply_ok(value: Any) -> bytes:
    return dumps(("ok", value))


def encode_reply_error(exc: BaseException) -> bytes:
    tb = "".join(traceback.format_exception(type(exc), exc, exc.__traceback__))
    try:
        payload = dumps(("err", exc, tb))
    except Exception:
        payload = dumps(("err", RemoteError(repr(exc)), tb))
    return payload


def decode_reply(data: bytes) -> Any:
    msg = loads(data)
    if msg[0] == "ok":
        return msg[1]
    _, exc, tb = msg
    raise RemoteError(f"remote call failed:\n{tb}") from exc
