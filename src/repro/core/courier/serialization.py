"""Courier wire format: length-prefixed frames with out-of-band buffers.

Layout of a framed message (all integers little-endian)::

    MAGIC(2B) | nframes:u32 | len_0:u64 .. len_{n-1}:u64 | frame_0 | .. | frame_{n-1}

``frame_0`` is a pickle protocol-5 stream produced with a
``buffer_callback``; frames 1..n-1 are the raw out-of-band buffers
(numpy / JAX array payloads) it references. Array payloads are therefore
never copied into the pickle stream on encode, and on decode they are
reconstructed as zero-copy views over the received message — received
arrays are read-only; call ``np.copy`` before mutating in place.

JAX arrays are reduced through numpy at pickling time (device buffers do
not transport); they come back as numpy and re-device-put lazily on use.
There is no pre-serialization deep-copy pass over the payload: container
types (including NamedTuple subclasses) are preserved exactly as pickle
sees them.

A message that does not start with MAGIC is treated as a bare cloudpickle
blob — the pre-frames legacy format, kept for wire compatibility and as
the benchmark baseline (see ``legacy_dumps``). ``loads`` transparently
decodes both.

Transports that own a writable destination buffer (the shm ring / slot
pools) skip the ``bytes`` join entirely via the scatter-gather API:
``encode_frames`` / ``framed_size`` / ``write_framed_into`` /
``framed_chunks`` / ``encode_call_into`` — each array payload is copied
exactly once, source array -> destination memory.

On the receive side, ``loads_owned`` decodes a framed message *in place*
over transport-owned memory (an shm pool slot) and threads an owner (the
slot's lease) under every decoded array, so the transport can reuse the
memory exactly when the consumer drops the message. ``owner_of`` /
``materialize`` let consumers inspect and detach such views.
"""

from __future__ import annotations

import io
import pickle
import struct
import traceback
from typing import Any, Sequence

import cloudpickle
import numpy as np

MAGIC = b"\xc5\x01"  # 'courier frames', version 1
_NFRAMES = struct.Struct("<I")
_FRAMELEN = struct.Struct("<Q")

# Legacy (pre-frames) pickle streams start with the pickle PROTO opcode
# (0x80), so MAGIC can never collide with them.
assert MAGIC[0] != 0x80


class RemoteError(RuntimeError):
    """An exception raised inside a remote service, re-raised client-side."""


_JAX_ARRAY_TYPE: Any = False  # unresolved sentinel (None = jax unavailable)


def _jax_array_type():
    # Resolved once: reducer_override runs per pickled object, so the
    # import-machinery probe must not sit on the encode hot path.
    global _JAX_ARRAY_TYPE
    if _JAX_ARRAY_TYPE is False:
        try:
            import jax
            _JAX_ARRAY_TYPE = jax.Array
        except Exception:  # pragma: no cover - jax is a hard dep in this repo
            _JAX_ARRAY_TYPE = None
    return _JAX_ARRAY_TYPE


def _as_readonly(arr: np.ndarray) -> np.ndarray:
    view = arr.view()
    view.flags.writeable = False
    return view


class _CourierPickler(cloudpickle.CloudPickler):
    """cloudpickle plus device-array reduction.

    JAX arrays are reduced through ``np.asarray`` so device buffers never
    enter the stream; under protocol 5 numpy then emits the payload as an
    out-of-band ``PickleBuffer`` which the frame encoder ships uncopied.

    Array payloads are reduced through a *read-only view* on purpose: a
    readonly source makes the pickler emit the ``READONLY_BUFFER`` opcode,
    and on decode that opcode wraps the supplied buffer in
    ``memoryview(buf).toreadonly()`` — the wrap is what lets
    :func:`loads_owned` pin a transport lease under every decoded array
    (numpy collapses chains of *ndarray* bases, but stops at a
    memoryview), and what keeps received arrays read-only even when they
    alias writable shared memory.
    """

    def reducer_override(self, obj):
        jax_array = _jax_array_type()
        if jax_array is not None and isinstance(obj, jax_array):
            return _as_readonly(np.asarray(obj)).__reduce_ex__(5)
        if type(obj) is np.ndarray and obj.flags.writeable:
            # Plain ndarrays are the only types that emit out-of-band
            # buffers in this codebase (subclasses reduce in-band).
            return _as_readonly(obj).__reduce_ex__(5)
        return super().reducer_override(obj)


# ---- framed encode / decode -------------------------------------------------

def dumps(obj: Any) -> bytes:
    """Serialize ``obj`` into a framed message (out-of-band array buffers)."""
    frames = encode_frames(obj)
    parts: list[Any] = [MAGIC, _NFRAMES.pack(len(frames))]
    parts.extend(_FRAMELEN.pack(f.nbytes) for f in frames)
    parts.extend(frames)
    return b"".join(parts)


def is_framed(data: bytes) -> bool:
    return len(data) >= 2 and bytes(data[:2]) == MAGIC


# ---- scatter-gather encode ---------------------------------------------------
#
# ``dumps`` joins the pickle stream and every out-of-band buffer into one
# intermediate ``bytes`` — fine for gRPC (which needs a single message
# object anyway), but a wasted copy for transports that own a writable
# destination buffer (the shm ring / spill segments). The functions below
# expose the frame list itself so such transports can copy each payload
# exactly once, source array -> destination memory.

def encode_frames(obj: Any) -> list:
    """Pickle ``obj`` and return its frames uncombined.

    Element 0 is the protocol-5 pickle stream; elements 1..n-1 are the raw
    out-of-band buffers (views over the *original* arrays — nothing is
    copied). Pass the list to :func:`write_framed_into` /
    :func:`framed_size` or decode it with :func:`decode_frames`.
    """
    buffers: list[pickle.PickleBuffer] = []
    stream = io.BytesIO()
    _CourierPickler(stream, protocol=5, buffer_callback=buffers.append).dump(obj)
    frames: list[Any] = [stream.getbuffer()]
    for buf in buffers:
        try:
            frames.append(buf.raw())
        except BufferError:  # non-contiguous exotic buffer: copy once
            frames.append(memoryview(bytes(buf)))
    return frames


def framed_size(frames: Sequence) -> int:
    """Total byte size of the framed message :func:`write_framed_into` emits."""
    return (len(MAGIC) + _NFRAMES.size + _FRAMELEN.size * len(frames)
            + sum(memoryview(f).nbytes for f in frames))


# numpy's copy path beats memoryview slicing ~2x for large transfers on
# the kernels we deploy on; below this size its setup overhead loses.
_NP_COPY_MIN = 4096


def copy_into(out, offset: int, v) -> None:
    """Copy buffer ``v`` into ``out`` at ``offset`` at full memcpy speed."""
    v = memoryview(v).cast("B")
    if v.nbytes > _NP_COPY_MIN:
        np.copyto(
            np.frombuffer(out, np.uint8, count=v.nbytes, offset=offset),
            np.frombuffer(v, np.uint8))
    else:
        memoryview(out)[offset:offset + v.nbytes] = v


def read_copy(buf, offset: int, n: int):
    """Copy ``n`` bytes out of ``buf`` into fresh memory (bytes-like)."""
    if n > _NP_COPY_MIN:
        return np.frombuffer(buf, np.uint8, count=n, offset=offset).copy().data
    return bytes(memoryview(buf)[offset:offset + n])


def write_framed_into(buf, frames: Sequence) -> int:
    """Write the standard framed message directly into writable ``buf``.

    This is the scatter-gather twin of :func:`dumps`: each frame payload is
    copied exactly once into ``buf`` (no intermediate join). Returns the
    number of bytes written; raises ``ValueError`` if ``buf`` is too small.
    """
    out = memoryview(buf)
    total = framed_size(frames)
    if out.nbytes < total:
        raise ValueError(
            f"framed message needs {total} bytes; buffer has {out.nbytes}")
    out[:len(MAGIC)] = MAGIC
    offset = len(MAGIC)
    _NFRAMES.pack_into(out, offset, len(frames))
    offset += _NFRAMES.size
    views = [memoryview(f) for f in frames]
    for v in views:
        _FRAMELEN.pack_into(out, offset, v.nbytes)
        offset += _FRAMELEN.size
    for v in views:
        copy_into(out, offset, v)
        offset += v.nbytes
    return offset


def framed_chunks(frames: Sequence) -> list:
    """The framed message as a scatter list ``[header, frame_0, ...]``.

    Copy each element into the destination in order and you get exactly the
    bytes :func:`write_framed_into` produces — this is what the shm ring
    uses to gather a message into reserved ring space without a join.
    """
    views = [memoryview(f).cast("B") for f in frames]
    head = bytearray(MAGIC)
    head += _NFRAMES.pack(len(views))
    for v in views:
        head += _FRAMELEN.pack(v.nbytes)
    return [head, *views]


def encode_call_into(buf, method: str, args: tuple, kwargs: dict) -> int:
    """Scatter-gather :func:`encode_call`: frame the call directly into
    ``buf`` (e.g. a ring-buffer reservation), skipping the intermediate
    ``bytes`` that :func:`encode_call` produces. Returns bytes written."""
    return write_framed_into(buf, encode_frames((method, args, kwargs)))


def decode_frames(frames: Sequence) -> Any:
    """Decode a frame list produced by :func:`encode_frames` (or parsed off
    a framed message). Buffers alias the passed frames — zero-copy."""
    return pickle.loads(frames[0], buffers=[memoryview(f).cast("B")
                                            for f in frames[1:]])


def _parse_frame_spans(mv) -> list[tuple[int, int]]:
    """Parse a framed message's header: per-frame ``(offset, length)``."""
    (nframes,) = _NFRAMES.unpack_from(mv, 2)
    offset = 2 + _NFRAMES.size
    lengths = []
    for _ in range(nframes):
        (n,) = _FRAMELEN.unpack_from(mv, offset)
        lengths.append(n)
        offset += _FRAMELEN.size
    spans = []
    for n in lengths:
        spans.append((offset, n))
        offset += n
    return spans


def loads(data: bytes) -> Any:
    """Deserialize a framed message; falls back to bare-pickle (legacy)."""
    if not is_framed(data):
        return pickle.loads(data)
    mv = memoryview(data)
    frames = [mv[off:off + n] for off, n in _parse_frame_spans(mv)]
    # Buffers alias the received message: zero-copy, read-only arrays.
    return pickle.loads(frames[0], buffers=frames[1:])


# ---- decode with owner (transport-leased memory) ----------------------------
#
# ``loads`` over a transport-owned buffer (an shm slot) would hand out
# arrays whose lifetime the transport cannot see — it would never know
# when the slot may be reused. ``loads_owned`` threads an *owner* object
# (an ``shm.SlotLease``) under every decoded array: each out-of-band
# buffer handed to the unpickler is an ``_OwnedBuffer`` carrying the
# owner, the encoder's READONLY_BUFFER opcode wraps it in a memoryview
# (``.obj`` pins the _OwnedBuffer — numpy's view-base collapsing walks
# ndarray bases but stops at a memoryview), and so the owner's refcount
# hits zero exactly when the last decoded array dies. CPython refcounting
# makes the release prompt; the owner's ``__del__``/``release()`` then
# frees the slot.

class _OwnedBuffer(np.ndarray):
    """A uint8 view over transport-owned memory that keeps its owner (a
    slot lease) alive for as long as any decoded array aliases it."""

    _owner: Any = None


def loads_owned(view, owner: Any) -> Any:
    """Decode a framed message in place over transport-owned memory.

    ``view`` must be a *writable* buffer over the framed message (writable
    so the READONLY_BUFFER wrap actually happens — see ``_OwnedBuffer``);
    decoded arrays alias it, are read-only, and keep ``owner`` alive until
    the last of them is garbage-collected.
    """
    mv = memoryview(view).cast("B")
    if mv.readonly:
        raise ValueError(
            "loads_owned requires a writable view (a readonly buffer is "
            "passed through by the unpickler unwrapped, losing the owner)")
    if not (mv.nbytes >= 2 and mv[:2] == MAGIC):
        # Not a framed message (never produced by our slot writers):
        # decode a private copy, nothing can alias the slot.
        return pickle.loads(bytes(mv))
    spans = _parse_frame_spans(mv)
    (off0, n0), buf_spans = spans[0], spans[1:]
    buffers = []
    for offset, n in buf_spans:
        frame = np.frombuffer(mv, np.uint8, count=n,
                              offset=offset).view(_OwnedBuffer)
        frame.flags.writeable = True
        frame._owner = owner
        buffers.append(frame)
    return pickle.loads(mv[off0:off0 + n0], buffers=buffers)


def owner_of(arr: Any) -> Any:
    """The transport owner (slot lease) ``arr`` pins, or None.

    Walks the base chain: decoded array -> numpy view(s) -> the readonly
    memoryview the unpickler made -> the ``_OwnedBuffer`` carrying the
    owner."""
    node = arr
    while node is not None:
        if isinstance(node, _OwnedBuffer):
            return node._owner
        if isinstance(node, np.ndarray):
            node = node.base
        elif isinstance(node, memoryview):
            node = node.obj
        else:
            return None
    return None


def materialize(obj: Any) -> Any:
    """Deep-copy every transport-owned array view inside ``obj``.

    A decoded message's arrays may alias a shared-memory slot; holding
    them long-term pins the slot (starving the sender's slot pool).
    ``materialize`` returns an equivalent structure whose arrays own their
    memory, releasing the underlying lease(s) once the original is
    dropped. Containers (list/tuple/dict, incl. NamedTuples) are rebuilt
    only along paths that contain owned arrays."""
    if isinstance(obj, np.ndarray):
        return obj.copy() if owner_of(obj) is not None else obj
    if isinstance(obj, (list, tuple)):
        conv = [materialize(v) for v in obj]
        if all(a is b for a, b in zip(conv, obj)):
            return obj
        if isinstance(obj, tuple):
            return type(obj)(*conv) if hasattr(obj, "_fields") \
                else tuple(conv)
        return conv
    if isinstance(obj, dict):
        conv = {k: materialize(v) for k, v in obj.items()}
        if all(conv[k] is obj[k] for k in obj):
            return obj
        return conv
    return obj


# ---- legacy (pre-frames) encode ---------------------------------------------
#
# Frozen copy of the original wire format: a recursive deep-copy pass that
# converts jax leaves to numpy, then one in-band cloudpickle blob. Kept so
# mixed-version peers interoperate and so benchmarks/rpc_overhead.py can
# measure the old format against the new one over the same server.

def _legacy_to_transportable(obj: Any) -> Any:
    jax_array = _jax_array_type()
    if jax_array is not None and isinstance(obj, jax_array):
        return np.asarray(obj)
    if isinstance(obj, (list, tuple)):
        conv = [_legacy_to_transportable(v) for v in obj]
        if isinstance(obj, tuple):
            # Preserve NamedTuple subclasses (the original code collapsed
            # them to plain tuples).
            return type(obj)(*conv) if hasattr(obj, "_fields") else tuple(conv)
        return conv
    if isinstance(obj, dict):
        return {k: _legacy_to_transportable(v) for k, v in obj.items()}
    return obj


def legacy_dumps(obj: Any) -> bytes:
    return cloudpickle.dumps(_legacy_to_transportable(obj), protocol=5)


def _dumps(obj: Any, legacy: bool) -> bytes:
    return legacy_dumps(obj) if legacy else dumps(obj)


# ---- call / reply framing ---------------------------------------------------

def encode_call(method: str, args: tuple, kwargs: dict,
                legacy: bool = False) -> bytes:
    return _dumps((method, args, kwargs), legacy)


def decode_call(data: bytes) -> tuple[str, tuple, dict]:
    return loads(data)


def encode_reply_ok(value: Any, legacy: bool = False) -> bytes:
    return _dumps(("ok", value), legacy)


def _error_tuple(exc: BaseException) -> tuple:
    tb = "".join(traceback.format_exception(type(exc), exc, exc.__traceback__))
    return ("err", exc, tb)


def encode_reply_error(exc: BaseException, legacy: bool = False) -> bytes:
    status = _error_tuple(exc)
    try:
        return _dumps(status, legacy)
    except Exception:  # unpicklable exception object
        return _dumps(("err", RemoteError(repr(exc)), status[2]), legacy)


def _raise_remote(status: tuple) -> None:
    _, exc, tb = status
    raise RemoteError(f"remote call failed:\n{tb}") from exc


def decode_reply(data: bytes) -> Any:
    msg = loads(data)
    if msg[0] == "ok":
        return msg[1]
    _raise_remote(msg)


# ---- batch call / reply framing ---------------------------------------------
#
# A batch ships N calls in ONE framed message (one pickle stream, one set of
# shared out-of-band buffers) and returns N per-call statuses in one reply.
# Statuses preserve request order; a failing call never aborts its siblings.

def encode_batch_call(calls: Sequence[tuple[str, tuple, dict]],
                      legacy: bool = False) -> bytes:
    return _dumps(("batch", list(calls)), legacy)


def decode_batch_call(data: bytes) -> list[tuple[str, tuple, dict]]:
    tag, calls = loads(data)
    if tag != "batch":
        raise ValueError(f"not a batch call message: {tag!r}")
    return calls


def encode_batch_reply(statuses: Sequence[tuple], legacy: bool = False) -> bytes:
    statuses = list(statuses)
    try:
        # Fast path: one pickling pass over the whole batch.
        return _dumps(("batch_reply", statuses), legacy)
    except Exception:
        pass
    # Some status is unpicklable (an exotic exception, or an 'ok' value such
    # as a lock/handle). Isolate per status so siblings still come back.
    safe = []
    for status in statuses:
        try:
            _dumps(status, legacy)
            safe.append(status)
        except Exception:
            if status[0] == "ok":
                safe.append(("err", RemoteError(
                    f"result of type {type(status[1]).__name__} is not "
                    "serializable"), ""))
            else:
                safe.append(("err", RemoteError(repr(status[1])), status[2]))
    return _dumps(("batch_reply", safe), legacy)


def make_ok_status(value: Any) -> tuple:
    return ("ok", value)


def make_error_status(exc: BaseException) -> tuple:
    return _error_tuple(exc)


def decode_batch_reply(data: bytes) -> list[tuple]:
    msg = loads(data)
    if msg[0] == "err":  # whole-batch failure (e.g. undecodable request)
        _raise_remote(msg)
    tag, statuses = msg
    if tag != "batch_reply":
        raise ValueError(f"not a batch reply message: {tag!r}")
    return statuses


def status_to_result(status: tuple) -> Any:
    """Unwrap one batch status: return the value or raise RemoteError."""
    if status[0] == "ok":
        return status[1]
    _raise_remote(status)


def status_to_exception(status: tuple) -> RemoteError:
    """Build (without raising) the client-side error for an 'err' status."""
    _, exc, tb = status
    err = RemoteError(f"remote call failed:\n{tb}")
    err.__cause__ = exc
    return err
