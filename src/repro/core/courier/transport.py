"""Pluggable courier transports (paper §4.1).

A :class:`Transport` moves one call — ``(method, args, kwargs)`` — or one
batch of calls to a service and returns the result(s). The unified
:class:`~repro.core.courier.client.CourierClient` owns all proxy sugar
(attribute methods, ``.futures``, ``batch_call``) and delegates the actual
movement here, so the gRPC and in-process paths no longer duplicate it.

Implementations:

``GrpcTransport``    framed wire format (serialization.py) over pooled
                     gRPC channels. Channels are shared process-wide,
                     keyed by ``host:port`` and refcounted: N clients to
                     the same endpoint share one channel; the channel
                     closes when the last client is closed.
``ShmTransport``     same-host cross-process fast path: framed messages
                     over shared-memory rings (shm.py), with a reply-
                     correlation map so futures pipeline without waiting
                     on each other.
``InProcTransport``  direct method invocation against the in-process
                     registry (zero serialization); ``.futures`` runs on a
                     shared thread pool. Used when launch placed caller
                     and service in the same process.

An endpoint may carry several candidate schemes joined by ``+``
(preferred first), e.g. ``shm://name+grpc://127.0.0.1:9000``:
:func:`make_transport` picks the first viable one, so a same-host client
gets the shm ring and a remote (or shm-less) client transparently falls
back to gRPC.
"""

from __future__ import annotations

import abc
import contextlib
import re
import threading
import time
from concurrent import futures as cf
from typing import Any, Callable, Optional, Sequence

import grpc

from repro.core import telemetry
from repro.core.courier import inprocess
from repro.core.courier import serialization as ser
from repro.core.courier import shm as shm_mod

# One call: (method, args, kwargs). One status: ("ok", value) | ("err", ...).
Call = tuple[str, tuple, dict]


class TransportStats:
    """Per-transport I/O counters. Plain attribute adds (GIL-atomic
    enough for telemetry) — the record path takes no locks. ``bytes_*``
    count serialized payloads where a wire exists (gRPC/shm); the inproc
    transport moves objects, so its byte counters stay zero."""

    __slots__ = ("calls", "batch_calls", "batched_calls_in_frames",
                 "errors", "bytes_out", "bytes_in", "serialize_us",
                 "pool_grows")

    def __init__(self):
        self.calls = 0
        self.batch_calls = 0
        self.batched_calls_in_frames = 0
        self.errors = 0
        self.bytes_out = 0
        self.bytes_in = 0
        self.serialize_us = 0.0
        self.pool_grows = 0

    def as_dict(self) -> dict:
        return {"calls": self.calls, "batch_calls": self.batch_calls,
                "batched_calls_in_frames": self.batched_calls_in_frames,
                "errors": self.errors, "bytes_out": self.bytes_out,
                "bytes_in": self.bytes_in,
                "serialize_us": self.serialize_us,
                "pool_grows": self.pool_grows}

_GRPC_OPTIONS = [
    ("grpc.max_send_message_length", -1),
    ("grpc.max_receive_message_length", -1),
    # Launchers reserve ports by holding a bound SO_REUSEPORT socket open
    # until the server binds (closes the pick-then-bind TOCTOU window), so
    # the server must bind with SO_REUSEPORT too. Default on Linux; pinned
    # here so the reservation scheme cannot silently break.
    ("grpc.so_reuseport", 1),
]

COURIER_METHOD = "/courier/Call"
COURIER_BATCH_METHOD = "/courier/BatchCall"

# First-contact deadline for gRPC transports. wait_for_ready=True exists so
# calls issued before the server node finished binding do not fail, but with
# timeout=None it blocks *forever* on an endpoint that never comes up; this
# bounds the wait with a clear error instead. Overridable per client via
# the existing timeout plumbing (CourierClient(endpoint, timeout=...)).
CONNECT_TIMEOUT_S = 20.0


class Transport(abc.ABC):
    """Moves calls to one service endpoint."""

    endpoint: str

    def __init__(self):
        self._io = TransportStats()

    def stats(self) -> dict:
        """Cumulative I/O counters (calls, batched calls, bytes in/out,
        errors, serialize time, slot-pool grow events) — the transport's
        contribution to a node's ``telemetry()`` report."""
        return self._io.as_dict()

    @abc.abstractmethod
    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        """Execute one call synchronously; return its result or raise."""

    @abc.abstractmethod
    def call_future(self, method: str, args: tuple, kwargs: dict) -> cf.Future:
        """Execute one call asynchronously."""

    @abc.abstractmethod
    def batch_call(self, calls: Sequence[Call]) -> list[tuple]:
        """Execute N calls in one round trip; return N statuses in order."""

    @abc.abstractmethod
    def batch_call_future(self, calls: Sequence[Call]) -> cf.Future:
        """Async :meth:`batch_call`; the future resolves to the status list."""

    def close(self) -> None:  # noqa: B027 - optional hook
        """Release transport resources. Idempotent."""


# ---- gRPC channel pool ------------------------------------------------------

class _ChannelPool:
    """Process-wide refcounted channel cache keyed by ``host:port``.

    gRPC channels are expensive (socket + HTTP/2 session + threads) and
    fully thread-safe, so every transport to the same endpoint shares one.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: dict[str, tuple[grpc.Channel, int]] = {}

    def acquire(self, target: str) -> grpc.Channel:
        with self._lock:
            entry = self._entries.get(target)
            if entry is None:
                channel = grpc.insecure_channel(target, options=_GRPC_OPTIONS)
                self._entries[target] = (channel, 1)
                return channel
            channel, refs = entry
            self._entries[target] = (channel, refs + 1)
            return channel

    def release(self, target: str) -> None:
        with self._lock:
            entry = self._entries.get(target)
            if entry is None:
                return
            channel, refs = entry
            if refs <= 1:
                del self._entries[target]
            else:
                self._entries[target] = (channel, refs - 1)
                return
        channel.close()

    def stats(self) -> dict[str, int]:
        """target -> refcount (test/debug hook)."""
        with self._lock:
            return {t: refs for t, (_, refs) in self._entries.items()}


_channel_pool = _ChannelPool()


def channel_pool_stats() -> dict[str, int]:
    return _channel_pool.stats()


def _wrap_rpc_error(endpoint: str, exc: grpc.RpcError) -> ser.RemoteError:
    """Transport-level failures (channel broken, server gone, deadline)
    surface as RemoteError naming the endpoint, like remote exceptions."""
    code = exc.code() if hasattr(exc, "code") else None
    details = exc.details() if hasattr(exc, "details") else ""
    return ser.RemoteError(
        f"courier call to {endpoint} failed: {code} {details}".rstrip())


class _DecodingFuture(cf.Future):
    """Adapts a grpc future into a concurrent.futures.Future, decoding the
    raw reply bytes with ``decode`` on completion."""

    @classmethod
    def wrap(cls, grpc_future, decode: Callable[[bytes], Any],
             endpoint: str, io: Optional["TransportStats"] = None
             ) -> "cf.Future":
        out = cls()
        out.set_running_or_notify_cancel()

        def _done(gf):
            try:
                out.set_result(decode(gf.result()))
            except grpc.RpcError as exc:
                if io is not None:
                    io.errors += 1
                out.set_exception(_wrap_rpc_error(endpoint, exc))
            except BaseException as exc:  # noqa: BLE001
                out.set_exception(exc)

        grpc_future.add_done_callback(_done)
        return out


class GrpcTransport(Transport):
    """Courier-over-gRPC with pooled channels and framed serialization.

    ``wire_format="frames"`` (default) uses the protocol-5 out-of-band
    frame format; ``"legacy"`` emits the pre-frames bare-cloudpickle blobs
    (the server mirrors whichever format the request used — this is the
    benchmark baseline and the mixed-version compatibility path).
    """

    def __init__(self, endpoint: str, timeout: Optional[float] = None,
                 wire_format: str = "frames"):
        super().__init__()
        if endpoint.startswith("grpc://"):
            endpoint = endpoint[len("grpc://"):]
        if wire_format not in ("frames", "legacy"):
            raise ValueError(f"unknown wire_format {wire_format!r}")
        self.endpoint = f"grpc://{endpoint}"
        self._target = endpoint
        self._timeout = timeout
        self._legacy = wire_format == "legacy"
        self._lock = threading.Lock()
        self._channel: Optional[grpc.Channel] = None
        self._unary = None
        self._unary_batch = None
        self._closed = False
        self._ready = False

    # -- channel lifecycle ---------------------------------------------------
    def _callables(self, ensure_ready: bool = False):
        with self._lock:
            if self._closed:
                raise RuntimeError(f"transport to {self.endpoint} is closed")
            if self._channel is None:
                self._channel = _channel_pool.acquire(self._target)
                self._unary = self._channel.unary_unary(
                    COURIER_METHOD,
                    request_serializer=None, response_deserializer=None)
                self._unary_batch = self._channel.unary_unary(
                    COURIER_BATCH_METHOD,
                    request_serializer=None, response_deserializer=None)
            channel = self._channel
            unary, unary_batch = self._unary, self._unary_batch
        if ensure_ready and not self._ready:
            # First contact on the *synchronous* paths: bound wait for the
            # endpoint to exist at all, so a typo'd or never-started server
            # errors out instead of blocking forever under wait_for_ready.
            # Future-returning paths skip this (they must not block the
            # caller during asynchronous launch). Probed with an RPC to a
            # reserved method — UNIMPLEMENTED proves the server is up —
            # rather than channel_ready_future, whose connectivity
            # subscription leaks a polling thread that crashes when the
            # channel closes.
            deadline = self._timeout if self._timeout is not None \
                else CONNECT_TIMEOUT_S
            probe = channel.unary_unary("/courier/__ready__")
            try:
                probe(b"", timeout=deadline, wait_for_ready=True)
            except grpc.RpcError as exc:
                code = exc.code() if hasattr(exc, "code") else None
                if code == grpc.StatusCode.DEADLINE_EXCEEDED:
                    raise ser.RemoteError(
                        f"courier endpoint {self.endpoint} did not become "
                        f"reachable within {deadline:.1f}s (server down, "
                        "still starting, or wrong address)") from None
                if code != grpc.StatusCode.UNIMPLEMENTED:
                    raise _wrap_rpc_error(self.endpoint, exc) from exc
            self._ready = True
        return unary, unary_batch

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            had_channel = self._channel is not None
            self._channel = None
            self._unary = None
            self._unary_batch = None
        if had_channel:
            _channel_pool.release(self._target)

    # -- calls ---------------------------------------------------------------
    def _encode(self, calls_or_one, batch: bool) -> bytes:
        io = self._io
        t0 = time.perf_counter()
        if batch:
            payload = ser.encode_batch_call(calls_or_one, legacy=self._legacy)
            io.batch_calls += 1
            io.batched_calls_in_frames += len(calls_or_one)
        else:
            method, args, kwargs = calls_or_one
            payload = ser.encode_call(method, args, kwargs,
                                      legacy=self._legacy)
            io.calls += 1
        io.serialize_us += (time.perf_counter() - t0) * 1e6
        io.bytes_out += len(payload)
        return payload

    def _decode_reply(self, reply: bytes):
        self._io.bytes_in += len(reply)
        return ser.decode_reply(reply)

    def _decode_batch_reply(self, reply: bytes):
        self._io.bytes_in += len(reply)
        return ser.decode_batch_reply(reply)

    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        unary, _ = self._callables(ensure_ready=True)
        payload = self._encode((method, args, kwargs), batch=False)
        try:
            # wait_for_ready: don't fail calls issued before the server node
            # finished binding (launch is asynchronous).
            reply = unary(payload, timeout=self._timeout, wait_for_ready=True)
        except grpc.RpcError as exc:
            self._io.errors += 1
            raise _wrap_rpc_error(self.endpoint, exc) from exc
        return self._decode_reply(reply)

    def call_future(self, method: str, args: tuple, kwargs: dict) -> cf.Future:
        unary, _ = self._callables()
        payload = self._encode((method, args, kwargs), batch=False)
        gf = unary.future(payload, timeout=self._timeout, wait_for_ready=True)
        return _DecodingFuture.wrap(gf, self._decode_reply, self.endpoint,
                                    io=self._io)

    def batch_call(self, calls: Sequence[Call]) -> list[tuple]:
        _, batch = self._callables(ensure_ready=True)
        payload = self._encode(calls, batch=True)
        try:
            reply = batch(payload, timeout=self._timeout, wait_for_ready=True)
        except grpc.RpcError as exc:
            self._io.errors += 1
            raise _wrap_rpc_error(self.endpoint, exc) from exc
        return self._decode_batch_reply(reply)

    def batch_call_future(self, calls: Sequence[Call]) -> cf.Future:
        _, batch = self._callables()
        payload = self._encode(calls, batch=True)
        gf = batch.future(payload, timeout=self._timeout, wait_for_ready=True)
        return _DecodingFuture.wrap(gf, self._decode_batch_reply,
                                    self.endpoint, io=self._io)

    def __repr__(self) -> str:
        fmt = "legacy" if self._legacy else "frames"
        return f"GrpcTransport({self.endpoint}, wire_format={fmt!r})"


class ShmTransport(Transport):
    """Courier over a shared-memory ring pair (same-host processes only).

    One SPSC ring per direction (shm.py): requests are scatter-gathered
    straight into the ring (``serialization.encode_frames`` +
    ``framed_chunks`` — no intermediate ``bytes``), large messages go
    through the per-direction slot pool, and replies resolve through a
    req-id -> Future correlation map so ``call_future``/``batch_call``
    pipeline: N in-flight calls share the rings without blocking each
    other.

    Large replies are decoded **zero-copy**: result arrays are read-only
    views aliasing a pool slot, pinned by a lease that frees the slot
    when the decoded result is garbage-collected. Callers that retain a
    result long-term should ``np.copy`` it (or
    ``serialization.materialize`` the whole result) so the sender's pool
    is not starved. ``zero_copy=False`` restores the copy-out receive on
    both directions of the connection — the paired A/B baseline in
    benchmarks/rpc_overhead.py.

    Receiving is *caller-driven*: the thread blocked in a synchronous
    ``call`` takes the drive lock and drains the reply ring itself
    (fulfilling any other caller's futures it encounters on the way),
    which keeps the hot path free of reader-thread/condvar hand-offs; a
    fallback daemon thread drives only while futures are outstanding with
    no active driver. If the server process dies, pending futures fail
    with a RemoteError naming the endpoint (no deadlock).
    """

    def __init__(self, endpoint: str, timeout: Optional[float] = None,
                 connect_wait: Optional[float] = None,
                 zero_copy: bool = True):
        super().__init__()
        if endpoint.startswith("shm://"):
            endpoint = endpoint[len("shm://"):]
        self.endpoint = f"shm://{endpoint}"
        self._timeout = timeout
        # Raises ShmConnectError if no healthy listener; make_transport
        # catches it to fall back to gRPC.
        self._conn = shm_mod.ClientConnection.connect(
            endpoint, wait=connect_wait, zero_copy=zero_copy)
        self._pending: dict[int, cf.Future] = {}
        self._plock = threading.Lock()
        self._drive_lock = threading.Lock()
        self._work = threading.Event()
        self._next_id = 0
        self._closed = False
        self._broken: Optional[BaseException] = None
        self._fallback = threading.Thread(
            target=self._fallback_drive,
            name=f"courier-shm-client/{endpoint}", daemon=True)
        self._fallback.start()

    # -- reply correlation ----------------------------------------------------
    def _dispatch(self, rec) -> None:
        kind, req_id, obj = rec
        with self._plock:
            fut = self._pending.pop(req_id, None)
        if fut is None:
            return  # cancelled/unknown; drop
        if isinstance(obj, shm_mod.DecodeFailure):
            fut.set_exception(ser.RemoteError(
                f"reply from {self.endpoint} failed to decode: "
                f"{obj.exc!r}"))
        elif kind == shm_mod.KIND_REPLY:
            if obj[0] == "ok":
                fut.set_result(obj[1])
            else:
                fut.set_exception(ser.status_to_exception(obj))
        elif kind == shm_mod.KIND_BATCH_REPLY:
            fut.set_result(obj)

    def _drive_once(self, timeout: float) -> None:
        """Receive+dispatch at most one reply. Marks the transport broken
        (failing every pending future) on peer death or a dead ring."""
        try:
            rec = self._conn.recv(timeout=timeout)
        except shm_mod.RingClosed:
            self._fail_pending(ser.RemoteError(
                f"courier endpoint {self.endpoint} closed by peer"))
            return
        except Exception as exc:  # undecodable stream; KeyboardInterrupt
            # and friends must propagate to the driving caller instead.
            self._fail_pending(ser.RemoteError(
                f"courier endpoint {self.endpoint} sent an undecodable "
                f"reply: {exc!r}"))
            return
        if rec is None:
            if not self._conn.peer_alive() and not self._closed:
                self._fail_pending(ser.RemoteError(
                    f"courier endpoint {self.endpoint}: server process "
                    "died"))
            return
        self._dispatch(rec)

    def _fallback_drive(self) -> None:
        """Covers futures nobody is awaiting synchronously. Sleeps on an
        event while the transport is idle (no polling cost), woken by
        ``_submit``."""
        while not self._closed and self._broken is None:
            if not self._pending:
                self._work.wait(timeout=0.5)
                self._work.clear()
                continue
            if self._drive_lock.acquire(timeout=0.05):
                try:
                    while (not self._closed and self._broken is None
                           and self._pending):
                        self._drive_once(timeout=0.05)
                except BaseException:  # noqa: BLE001 - daemon must not die
                    if self._broken is None and not self._closed:
                        self._fail_pending(ser.RemoteError(
                            f"courier endpoint {self.endpoint}: reply "
                            "drain failed"))
                    return
                finally:
                    self._drive_lock.release()

    def _fail_pending(self, exc: BaseException) -> None:
        # _broken is published under _plock, in the same critical section
        # that empties the map: a _submit either sees _broken and raises,
        # or registers its future before the clear and gets it failed —
        # never an orphaned pending future nobody will resolve.
        with self._plock:
            self._broken = exc
            pending = list(self._pending.values())
            self._pending.clear()
        self._io.errors += len(pending)
        for fut in pending:
            if not fut.done():
                fut.set_exception(exc)

    def _submit(self, kind: int, payload) -> tuple[int, cf.Future]:
        if self._closed:
            raise RuntimeError(f"transport to {self.endpoint} is closed")
        fut: cf.Future = cf.Future()
        fut.set_running_or_notify_cancel()
        with self._plock:
            if self._broken is not None:
                raise ser.RemoteError(
                    f"courier endpoint {self.endpoint} is broken: "
                    f"{self._broken}")
            self._next_id += 1
            req_id = self._next_id
            self._pending[req_id] = fut
        self._work.set()  # wake the fallback driver for this request
        try:
            self._conn.send(kind, req_id, payload, timeout=self._timeout)
        except BaseException as exc:
            with self._plock:
                self._pending.pop(req_id, None)
            if isinstance(exc, shm_mod.RingClosed):
                raise ser.RemoteError(
                    f"courier endpoint {self.endpoint} is gone: {exc}"
                ) from exc
            raise
        return req_id, fut

    def _timed_out(self, req_id: int) -> ser.RemoteError:
        # Un-register the request so a reply that never comes cannot keep
        # the fallback driver awake (and the map from growing) forever; a
        # late reply for this id is simply dropped by _dispatch.
        with self._plock:
            self._pending.pop(req_id, None)
        return ser.RemoteError(
            f"courier call to {self.endpoint} timed out after "
            f"{self._timeout}s")

    def _await(self, req_id: int, fut: cf.Future) -> Any:
        deadline = None if self._timeout is None \
            else time.monotonic() + self._timeout
        while not fut.done():
            if self._closed:
                break
            if deadline is not None and time.monotonic() >= deadline:
                raise self._timed_out(req_id)
            if self._drive_lock.acquire(blocking=False):
                try:
                    while not fut.done() and not self._closed \
                            and self._broken is None:
                        self._drive_once(timeout=0.05)
                        if deadline is not None \
                                and time.monotonic() >= deadline \
                                and not fut.done():
                            raise self._timed_out(req_id)
                finally:
                    self._drive_lock.release()
            else:
                # Another thread is driving; it will fulfil our future.
                with contextlib.suppress(cf.TimeoutError):
                    fut.result(timeout=0.005)
                if deadline is not None and time.monotonic() >= deadline \
                        and not fut.done():
                    raise self._timed_out(req_id)
        if not fut.done():
            # Raced with close(): _closed was observed before close's
            # _fail_pending resolved our future.
            raise ser.RemoteError(
                f"transport to {self.endpoint} was closed")
        return fut.result(timeout=0)

    # -- calls ---------------------------------------------------------------
    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        self._io.calls += 1
        return self._await(*self._submit(shm_mod.KIND_CALL,
                                         (method, args, kwargs)))

    def call_future(self, method: str, args: tuple, kwargs: dict) -> cf.Future:
        self._io.calls += 1
        return self._submit(shm_mod.KIND_CALL, (method, args, kwargs))[1]

    def batch_call(self, calls: Sequence[Call]) -> list[tuple]:
        self._io.batch_calls += 1
        self._io.batched_calls_in_frames += len(calls)
        return self._await(*self._submit(shm_mod.KIND_BATCH, list(calls)))

    def batch_call_future(self, calls: Sequence[Call]) -> cf.Future:
        self._io.batch_calls += 1
        self._io.batched_calls_in_frames += len(calls)
        return self._submit(shm_mod.KIND_BATCH, list(calls))[1]

    def stats(self) -> dict:
        """Transport counters plus the connection's wire-level I/O —
        bytes actually carried by the rings (serialize time included)
        and slot-pool grow events on the send channel."""
        out = self._io.as_dict()
        io = getattr(self._conn, "io_stats", None)
        if callable(io):
            conn = io()
            out["bytes_out"] += conn["bytes_out"]
            out["bytes_in"] += conn["bytes_in"]
            out["serialize_us"] += conn["serialize_us"]
            out["pool_grows"] += conn["pool_grows"]
        return out

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._work.set()  # wake the fallback driver so it can exit
        self._fail_pending(ser.RemoteError(
            f"transport to {self.endpoint} was closed"))
        self._conn.close()
        self._fallback.join(timeout=2.0)
        self._conn.release()

    def __repr__(self) -> str:
        return f"ShmTransport({self.endpoint})"


class InProcTransport(Transport):
    """Same-process fast path: direct invocation, zero serialization.

    Mirrors the gRPC server's exposure rules (no ``run``, no ``_private``)
    so a program behaves the same whichever transport launch picked.
    Exceptions propagate as the *original* exception objects — there is no
    wire to strip tracebacks — except batch statuses, which carry them
    unmodified in the ``err`` slot.
    """

    def __init__(self, name: str):
        super().__init__()
        self.endpoint = f"inproc://{name}"
        self._name = name
        self._obj = None

    def _target_obj(self) -> Any:
        if self._obj is None:
            self._obj = inprocess.lookup(self._name)
        return self._obj

    def _resolve(self, method: str):
        if method.startswith("_") or method == "run":
            raise ser.RemoteError(
                f"method {method!r} is not exposed over courier")
        return getattr(self._target_obj(), method)

    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        # Mirror the server chokepoint: pop the trace envelope and run the
        # handler under it, so a sampled request traces identically
        # whichever transport launch picked. kwargs is copied first — the
        # caller may share the dict (e.g. a retried batch entry).
        self._io.calls += 1
        ctx = None
        if telemetry.TRACE_KEY in kwargs:
            kwargs = dict(kwargs)
            ctx = telemetry.extract(kwargs)
        try:
            if ctx is not None:
                with telemetry.activate(ctx):
                    return self._resolve(method)(*args, **kwargs)
            return self._resolve(method)(*args, **kwargs)
        except BaseException:
            self._io.errors += 1
            raise

    def call_future(self, method: str, args: tuple, kwargs: dict) -> cf.Future:
        return inprocess.shared_pool().submit(self.call, method, args, kwargs)

    def batch_call(self, calls: Sequence[Call]) -> list[tuple]:
        self._io.batch_calls += 1
        self._io.batched_calls_in_frames += len(calls)
        statuses = []
        for method, args, kwargs in calls:
            try:
                statuses.append(ser.make_ok_status(self.call(method, args,
                                                             kwargs)))
            except BaseException as exc:  # noqa: BLE001 - per-call isolation
                statuses.append(ser.make_error_status(exc))
        return statuses

    def batch_call_future(self, calls: Sequence[Call]) -> cf.Future:
        return inprocess.shared_pool().submit(self.batch_call, list(calls))

    def __repr__(self) -> str:
        return f"InProcTransport({self.endpoint})"


def _is_grpc_endpoint(ep: str) -> bool:
    # grpc://host:port, or a bare host:port (numeric port) for convenience.
    return ep.startswith("grpc://") or re.fullmatch(r"[^:/]+:\d+", ep) is not None


def _try_shm(name: str, timeout: Optional[float],
             has_fallback: bool) -> Optional[Transport]:
    """Connect over shm if a healthy same-host listener is (or comes) up.

    ``ClientConnection.connect`` owns the rendezvous policy: an absent
    listener gets a grace period (``shm.CONNECT_WAIT_S`` — launch is
    asynchronous, same idea as gRPC's wait_for_ready), while a *stale*
    one (rendezvous left by a crashed server, or a different host) fails
    immediately so the caller falls back instead of deadlocking on dead
    shared memory.
    """
    try:
        return ShmTransport(name, timeout=timeout)
    except shm_mod.ShmConnectError as exc:
        if has_fallback:
            return None
        raise ser.RemoteError(
            f"shm connect failed and the endpoint has no fallback: {exc}"
        ) from exc


def make_transport(endpoint: str, timeout: Optional[float] = None,
                   wire_format: str = "frames") -> Transport:
    """Build the most appropriate transport for a resolved endpoint.

    ``endpoint`` may be a single URI or a ``+``-joined candidate list
    (preferred first). Unknown schemes fail fast — with wait_for_ready a
    typo'd endpoint would otherwise block forever instead of erroring.
    """
    candidates = endpoint.split("+")
    grpc_ep = next((ep for ep in candidates if _is_grpc_endpoint(ep)), None)
    for ep in candidates:
        if ep.startswith("inproc://"):
            return InProcTransport(ep[len("inproc://"):])
        if ep.startswith("shm://"):
            # The shm transport only speaks the framed format; an explicit
            # legacy request (A/B tooling, mixed-version peers) must reach
            # a transport that honors it.
            if not shm_mod.supported() or wire_format != "frames":
                continue
            transport = _try_shm(ep[len("shm://"):], timeout,
                                 has_fallback=grpc_ep is not None)
            if transport is not None:
                return transport
            continue  # stale/unreachable listener: fall through to gRPC
        if _is_grpc_endpoint(ep):
            return GrpcTransport(ep, timeout=timeout, wire_format=wire_format)
        raise ValueError(f"unknown courier endpoint scheme: {ep!r}")
    raise ValueError(f"no viable transport for endpoint {endpoint!r}")
