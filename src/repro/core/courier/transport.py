"""Pluggable courier transports (paper §4.1).

A :class:`Transport` moves one call — ``(method, args, kwargs)`` — or one
batch of calls to a service and returns the result(s). The unified
:class:`~repro.core.courier.client.CourierClient` owns all proxy sugar
(attribute methods, ``.futures``, ``batch_call``) and delegates the actual
movement here, so the gRPC and in-process paths no longer duplicate it.

Implementations:

``GrpcTransport``    framed wire format (serialization.py) over pooled
                     gRPC channels. Channels are shared process-wide,
                     keyed by ``host:port`` and refcounted: N clients to
                     the same endpoint share one channel; the channel
                     closes when the last client is closed.
``InProcTransport``  direct method invocation against the in-process
                     registry (zero serialization); ``.futures`` runs on a
                     shared thread pool. Used when launch placed caller
                     and service in the same process.
"""

from __future__ import annotations

import abc
import re
import threading
from concurrent import futures as cf
from typing import Any, Callable, Optional, Sequence

import grpc

from repro.core.courier import inprocess
from repro.core.courier import serialization as ser

# One call: (method, args, kwargs). One status: ("ok", value) | ("err", ...).
Call = tuple[str, tuple, dict]

_GRPC_OPTIONS = [
    ("grpc.max_send_message_length", -1),
    ("grpc.max_receive_message_length", -1),
]

COURIER_METHOD = "/courier/Call"
COURIER_BATCH_METHOD = "/courier/BatchCall"


class Transport(abc.ABC):
    """Moves calls to one service endpoint."""

    endpoint: str

    @abc.abstractmethod
    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        """Execute one call synchronously; return its result or raise."""

    @abc.abstractmethod
    def call_future(self, method: str, args: tuple, kwargs: dict) -> cf.Future:
        """Execute one call asynchronously."""

    @abc.abstractmethod
    def batch_call(self, calls: Sequence[Call]) -> list[tuple]:
        """Execute N calls in one round trip; return N statuses in order."""

    @abc.abstractmethod
    def batch_call_future(self, calls: Sequence[Call]) -> cf.Future:
        """Async :meth:`batch_call`; the future resolves to the status list."""

    def close(self) -> None:  # noqa: B027 - optional hook
        """Release transport resources. Idempotent."""


# ---- gRPC channel pool ------------------------------------------------------

class _ChannelPool:
    """Process-wide refcounted channel cache keyed by ``host:port``.

    gRPC channels are expensive (socket + HTTP/2 session + threads) and
    fully thread-safe, so every transport to the same endpoint shares one.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: dict[str, tuple[grpc.Channel, int]] = {}

    def acquire(self, target: str) -> grpc.Channel:
        with self._lock:
            entry = self._entries.get(target)
            if entry is None:
                channel = grpc.insecure_channel(target, options=_GRPC_OPTIONS)
                self._entries[target] = (channel, 1)
                return channel
            channel, refs = entry
            self._entries[target] = (channel, refs + 1)
            return channel

    def release(self, target: str) -> None:
        with self._lock:
            entry = self._entries.get(target)
            if entry is None:
                return
            channel, refs = entry
            if refs <= 1:
                del self._entries[target]
            else:
                self._entries[target] = (channel, refs - 1)
                return
        channel.close()

    def stats(self) -> dict[str, int]:
        """target -> refcount (test/debug hook)."""
        with self._lock:
            return {t: refs for t, (_, refs) in self._entries.items()}


_channel_pool = _ChannelPool()


def channel_pool_stats() -> dict[str, int]:
    return _channel_pool.stats()


class _DecodingFuture(cf.Future):
    """Adapts a grpc future into a concurrent.futures.Future, decoding the
    raw reply bytes with ``decode`` on completion."""

    @classmethod
    def wrap(cls, grpc_future, decode: Callable[[bytes], Any]) -> "cf.Future":
        out = cls()
        out.set_running_or_notify_cancel()

        def _done(gf):
            try:
                out.set_result(decode(gf.result()))
            except BaseException as exc:  # noqa: BLE001
                out.set_exception(exc)

        grpc_future.add_done_callback(_done)
        return out


class GrpcTransport(Transport):
    """Courier-over-gRPC with pooled channels and framed serialization.

    ``wire_format="frames"`` (default) uses the protocol-5 out-of-band
    frame format; ``"legacy"`` emits the pre-frames bare-cloudpickle blobs
    (the server mirrors whichever format the request used — this is the
    benchmark baseline and the mixed-version compatibility path).
    """

    def __init__(self, endpoint: str, timeout: Optional[float] = None,
                 wire_format: str = "frames"):
        if endpoint.startswith("grpc://"):
            endpoint = endpoint[len("grpc://"):]
        if wire_format not in ("frames", "legacy"):
            raise ValueError(f"unknown wire_format {wire_format!r}")
        self.endpoint = f"grpc://{endpoint}"
        self._target = endpoint
        self._timeout = timeout
        self._legacy = wire_format == "legacy"
        self._lock = threading.Lock()
        self._channel: Optional[grpc.Channel] = None
        self._unary = None
        self._unary_batch = None
        self._closed = False

    # -- channel lifecycle ---------------------------------------------------
    def _callables(self):
        with self._lock:
            if self._closed:
                raise RuntimeError(f"transport to {self.endpoint} is closed")
            if self._channel is None:
                self._channel = _channel_pool.acquire(self._target)
                self._unary = self._channel.unary_unary(
                    COURIER_METHOD,
                    request_serializer=None, response_deserializer=None)
                self._unary_batch = self._channel.unary_unary(
                    COURIER_BATCH_METHOD,
                    request_serializer=None, response_deserializer=None)
            return self._unary, self._unary_batch

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            had_channel = self._channel is not None
            self._channel = None
            self._unary = None
            self._unary_batch = None
        if had_channel:
            _channel_pool.release(self._target)

    # -- calls ---------------------------------------------------------------
    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        unary, _ = self._callables()
        payload = ser.encode_call(method, args, kwargs, legacy=self._legacy)
        # wait_for_ready: don't fail calls issued before the server node
        # finished binding (launch is asynchronous).
        reply = unary(payload, timeout=self._timeout, wait_for_ready=True)
        return ser.decode_reply(reply)

    def call_future(self, method: str, args: tuple, kwargs: dict) -> cf.Future:
        unary, _ = self._callables()
        payload = ser.encode_call(method, args, kwargs, legacy=self._legacy)
        gf = unary.future(payload, timeout=self._timeout, wait_for_ready=True)
        return _DecodingFuture.wrap(gf, ser.decode_reply)

    def batch_call(self, calls: Sequence[Call]) -> list[tuple]:
        _, batch = self._callables()
        payload = ser.encode_batch_call(calls, legacy=self._legacy)
        reply = batch(payload, timeout=self._timeout, wait_for_ready=True)
        return ser.decode_batch_reply(reply)

    def batch_call_future(self, calls: Sequence[Call]) -> cf.Future:
        _, batch = self._callables()
        payload = ser.encode_batch_call(calls, legacy=self._legacy)
        gf = batch.future(payload, timeout=self._timeout, wait_for_ready=True)
        return _DecodingFuture.wrap(gf, ser.decode_batch_reply)

    def __repr__(self) -> str:
        fmt = "legacy" if self._legacy else "frames"
        return f"GrpcTransport({self.endpoint}, wire_format={fmt!r})"


class InProcTransport(Transport):
    """Shared-memory fast path: direct invocation, zero serialization.

    Mirrors the gRPC server's exposure rules (no ``run``, no ``_private``)
    so a program behaves the same whichever transport launch picked.
    Exceptions propagate as the *original* exception objects — there is no
    wire to strip tracebacks — except batch statuses, which carry them
    unmodified in the ``err`` slot.
    """

    def __init__(self, name: str):
        self.endpoint = f"inproc://{name}"
        self._name = name
        self._obj = None

    def _target_obj(self) -> Any:
        if self._obj is None:
            self._obj = inprocess.lookup(self._name)
        return self._obj

    def _resolve(self, method: str):
        if method.startswith("_") or method == "run":
            raise ser.RemoteError(
                f"method {method!r} is not exposed over courier")
        return getattr(self._target_obj(), method)

    def call(self, method: str, args: tuple, kwargs: dict) -> Any:
        return self._resolve(method)(*args, **kwargs)

    def call_future(self, method: str, args: tuple, kwargs: dict) -> cf.Future:
        return inprocess.shared_pool().submit(self.call, method, args, kwargs)

    def batch_call(self, calls: Sequence[Call]) -> list[tuple]:
        statuses = []
        for method, args, kwargs in calls:
            try:
                statuses.append(ser.make_ok_status(self.call(method, args,
                                                             kwargs)))
            except BaseException as exc:  # noqa: BLE001 - per-call isolation
                statuses.append(ser.make_error_status(exc))
        return statuses

    def batch_call_future(self, calls: Sequence[Call]) -> cf.Future:
        return inprocess.shared_pool().submit(self.batch_call, list(calls))

    def __repr__(self) -> str:
        return f"InProcTransport({self.endpoint})"


def make_transport(endpoint: str, timeout: Optional[float] = None,
                   wire_format: str = "frames") -> Transport:
    """Build the most appropriate transport for a resolved endpoint."""
    if endpoint.startswith("inproc://"):
        return InProcTransport(endpoint[len("inproc://"):])
    # grpc://host:port, or a bare host:port (numeric port) for convenience.
    # Anything else fails fast — with wait_for_ready a typo'd endpoint
    # would otherwise block forever instead of erroring.
    if endpoint.startswith("grpc://") or re.fullmatch(
            r"[^:/]+:\d+", endpoint):
        return GrpcTransport(endpoint, timeout=timeout,
                             wire_format=wire_format)
    raise ValueError(f"unknown courier endpoint scheme: {endpoint!r}")
