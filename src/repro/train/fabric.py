"""Elastic actor–learner training fabric (paper §5.4 on the serve stack).

The Launchpad paper's training topologies — actor–learner and parameter
server — predate the discovery/rollout fabric PRs 5–8 built for serving.
This module ports them onto it, with the serve fleet's survival story:

``LearnerWorker``
    One data-parallel learner. Registers and heartbeats through the
    ``Registry`` like an engine replica (load reports carry steps/sec and
    the published model version). The *chief* learner (index 0 — chiefship
    is assigned at spawn, never self-elected, matching the paper's
    scheduler-restarts model) drives synchronous steps: it resolves the
    live peer set from the registry, fans ``compute_grads`` out to every
    peer via ``hedged_map`` (quorum over survivors, per-peer failures
    degrade the quorum instead of failing the step), averages the
    contributions, applies the update, and publishes ``{params, opt, ef}``
    to the versioned ``ModelStore`` every ``publish_every`` steps — actors
    always pull a consistent version, never an ad-hoc RPC snapshot.
    Gradients cross the wire dense or int8+error-feedback
    (``grad_compression``), selected by gradient size.

``ActorWorker``
    Generates experience with the latest published params and writes it
    into replay. A rate-limited insert that stalls past its deadline
    raises the typed ``WriterStalled`` (instead of blocking forever on a
    dead sampler); the actor fails over by re-resolving the replay
    service from the registry and keeps going.

``TrainSupervisor``
    Sibling of ``serve.rollout.RolloutController``: stateless over the
    registry's membership table. Detects dead workers (missed heartbeats
    → TTL eviction), respawns them under ``RestartPolicy`` backoff, and
    applies elastic resizes (``scale``): grown learners restore the
    latest published version onto their mesh via
    ``ckpt.elastic.restore_elastic``; shrunk learners are retired
    gracefully. A respawned chief restores from the last published
    version, so a learner death costs at most ``publish_every`` steps.

``ThreadWorkerSpawner``
    The in-process stand-in for "the scheduler restarts the executable":
    hosts workers on daemon threads behind inproc couriers, giving each
    respawn a fresh endpoint while the registry keeps the logical name.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import ModelStore
from repro.ckpt.elastic import restore_elastic
from repro.core import courier, telemetry
from repro.core.discovery import Heartbeater
from repro.core.fault import (FaultEvent, FaultInjector, RestartPolicy,
                              hedged_map)
from repro.core.nodes.base import (WorkerContext, get_current_context,
                                   set_current_context)
from repro.data.replay import ReplayServer, TableConfig, is_writer_stalled
from repro.train import grad_compression
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    """Knobs shared by every worker in one training fabric."""
    total_steps: int = 100
    batch_size: int = 32
    publish_every: int = 25            # bounded step loss on learner death
    grad_strategy: str = "auto"        # auto | dense | int8_ef
    compress_threshold_bytes: int = 1 << 22
    peer_timeout_s: float = 10.0       # chief's per-step fan-out deadline
    hedge_after_s: Optional[float] = None
    heartbeat_s: float = 0.2
    params_refresh_s: float = 0.1      # actor store-poll cadence
    insert_timeout_s: float = 1.0      # actor replay stall deadline
    sample_timeout_s: float = 1.0
    keep_versions: int = 10
    seed: int = 0


def host_tree(tree):
    """Device pytree -> picklable numpy pytree (the wire/ckpt form)."""
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def registry_resolver(registry: Any, role: str) -> Callable[[], Any]:
    """Resolve a live replica of ``role`` from the registry into a courier
    client — actors use this to *re*-resolve replay after a stall."""
    def resolve():
        for r in registry.lookup()["replicas"]:
            if r["load"].get("role") == role and not r.get("draining"):
                return courier.client_for(r["endpoint"])
        raise RuntimeError(f"no live {role!r} replica in registry")
    return resolve


class RegistryTarget:
    """A ``FaultInjector`` target addressed by *logical* name: the fault
    resolves the worker's current endpoint from the registry at fire time,
    so chaos schedules survive respawns (the respawned incarnation has a
    fresh endpoint but the same name)."""

    def __init__(self, registry: Any, name: str):
        self._registry = registry
        self._name = name

    def _client(self) -> Any:
        for r in self._registry.lookup()["replicas"]:
            if r["name"] == self._name:
                return courier.client_for(r["endpoint"])
        raise RuntimeError(f"{self._name!r} not live in registry")

    def kill(self) -> None:
        self._client().kill()

    def stall(self, seconds: float) -> None:
        self._client().stall(seconds)


class ChaosNode:
    """A PyNode-able fault injector addressed by logical worker names.

    ``schedule`` rows are ``(kind, name, after_s, duration_s)``; targets
    resolve through the registry at fire time (``RegistryTarget``), and
    ``after_s`` counts from when the target *first appears live* in the
    registry — worker startup (jit warmup, checkpoint restore) varies, so
    wall-clock-from-launch kills race it. The registry must be a
    *top-level* constructor arg so the launcher dereferences its handle —
    which is why this wrapper exists instead of handing
    ``RegistryTarget`` objects to ``FaultInjector`` directly.
    """

    def __init__(self, registry: Any, schedule):
        events, targets = [], []
        for i, (kind, name, after_s, duration_s) in enumerate(schedule):
            targets.append(RegistryTarget(registry, name))
            events.append(FaultEvent(
                kind, target=i, duration_s=duration_s,
                when=self._after_live(registry, name, after_s)))
        self.injector = FaultInjector(events, targets)

    @staticmethod
    def _after_live(registry: Any, name: str, delay_s: float):
        seen_at: dict[str, float] = {}

        def pred() -> bool:
            try:
                live = {r["name"] for r in registry.lookup()["replicas"]}
            except Exception:  # noqa: BLE001 - registry not up yet
                return False
            if name in live and "t0" not in seen_at:
                seen_at["t0"] = time.monotonic()
            return ("t0" in seen_at
                    and time.monotonic() - seen_at["t0"] >= delay_s)
        return pred

    def run(self) -> None:
        self.injector.run()


def replay_batch_fn(resolver: Callable[[], Any], table: str,
                    collate: Callable[[list], Any], batch_size: int,
                    timeout_s: float = 1.0) -> Callable[[], Any]:
    """A learner batch source over a replay service: sample, collate,
    ``None`` on timeout/error (caller retries; the client is re-resolved
    after an error so a replay restart heals)."""
    state: dict[str, Any] = {"client": None}

    def fn():
        if state["client"] is None:
            try:
                state["client"] = resolver()
            except Exception:  # noqa: BLE001 - replay not up yet
                return None
        try:
            items = state["client"].sample(table, batch_size, timeout_s)
        except Exception:  # noqa: BLE001 - replay died: re-resolve next call
            state["client"] = None
            return None
        if not items:
            return None
        return collate(items)
    return fn


class LearnerWorker:
    """One data-parallel learner; chief drives, peers serve gradients.

    ``task`` is duck-typed: ``init_params(key)``, ``optimizer``
    (an ``OptimizerConfig``), and ``grad_fn(params, batch) -> (loss,
    grads)`` (pure, jit-able). ``batch_fn()`` returns the next batch or
    ``None`` (retry). State is ``{"params", "opt", "ef"}`` — the int8
    error-feedback residual is real training state and rides in every
    published version (see ckpt/elastic.py).
    """

    def __init__(self, task, batch_fn: Callable[[], Any], store_dir: str,
                 registry: Any, cfg: FabricConfig, *, name: str = "learner-0",
                 chief: Optional[bool] = None, mesh=None,
                 endpoint: Optional[str] = None):
        self._task = task
        self._batch_fn = batch_fn
        self._registry = registry
        self._cfg = cfg
        self._name = name
        self._chief = name.endswith("-0") if chief is None else bool(chief)
        self._mesh = mesh
        self._store = ModelStore(store_dir, keep=cfg.keep_versions)
        self._grad_jit = jax.jit(task.grad_fn)
        self._lock = threading.Lock()
        self._dead = False
        self._retired = False
        self._done = False
        self._loss: Optional[float] = None
        self._steps_per_s = 0.0
        self._peer_clients: dict[str, tuple[str, Any]] = {}
        self._published: Optional[int] = None
        self._restored_from: Optional[int] = None

        params = task.init_params(jax.random.key(cfg.seed))
        like = {"params": params, "opt": opt_lib.init_opt_state(params),
                "ef": jax.tree.map(
                    lambda x: np.zeros(x.shape, np.float32), params)}
        latest = self._store.latest_version()
        if latest is not None:
            # Recovery/grow path: resume from the last *published* version,
            # resharded onto whatever mesh this incarnation runs on. The
            # step loss of a learner death is therefore bounded by
            # publish_every. fill_missing tolerates versions published
            # before the EF residual existed.
            tree = restore_elastic(self._store.version_dir(latest), like,
                                   new_mesh=mesh, fill_missing=True)
            self._step = int(latest)
            self._restored_from = int(latest)
            self._published = int(latest)
        else:
            if mesh is not None:
                from repro.ckpt.elastic import reshard
                like = reshard(like, mesh)
            tree = like
            self._step = 0
        self._params = tree["params"]
        self._opt = tree["opt"]
        self._ef = host_tree(tree["ef"])
        self._start_step = self._step
        self.history: list[tuple[int, float]] = []

        ctx = get_current_context()
        ep = endpoint or ctx.endpoint or f"inproc://{name}"
        self._heartbeater = Heartbeater(
            registry, name, ep, load_fn=self.load,
            period_s=cfg.heartbeat_s, stop_event=ctx.stop_event).start()

    # -- registry-facing -----------------------------------------------------
    def load(self) -> dict:
        return {"role": "learner", "chief": self._chief,
                "step": self._step, "start_step": self._start_step,
                "version": self._published, "loss": self._loss,
                "steps_per_s": round(self._steps_per_s, 3),
                "done": self._done}

    def telemetry(self) -> dict:
        """Standard hub scrape: process metrics/spans + this worker's load."""
        return telemetry.telemetry_snapshot(service=self.load())

    def get_status(self) -> dict:
        if self._dead:
            raise ConnectionError(f"{self._name} is dead")
        return self.load()

    # -- fault hooks (FaultInjector duck-type) -------------------------------
    def kill(self) -> None:
        """Die unannounced: heartbeats stop (no deregister — the registry
        finds out via TTL), RPCs fail, the run loop exits."""
        self._dead = True
        self._heartbeater.stop(deregister=False)

    def stall(self, seconds: float) -> None:
        self._heartbeater.pause(seconds)

    def retire(self) -> None:
        """Graceful scale-down: finish the in-flight call, deregister."""
        self._retired = True
        self._heartbeater.stop(deregister=True)

    # -- peer RPC surface ----------------------------------------------------
    def compute_grads(self, step: int, params_payload, strategy: str) -> dict:
        """Chief -> peer: gradient contribution at the chief's params.

        The peer compresses with its *own* error-feedback residual, so the
        chief sees uniformly quantized contributions and each worker's
        residual cancels its own bias over time.
        """
        if self._dead:
            raise ConnectionError(f"{self._name} is dead")
        with self._lock:
            self._params = params_payload
            self._step = int(step)
            batch = self._batch_fn()
            if batch is None:
                raise RuntimeError(f"{self._name}: no batch available")
            loss, grads = self._grad_jit(self._params, batch)
            if strategy == "int8_ef":
                payload, self._ef = grad_compression.compress_tree(
                    grads, self._ef, method="int8_ef")
            else:
                payload, _ = grad_compression.compress_tree(
                    grads, None, method="dense")
            self._loss = float(loss)
            return {"loss": float(loss), "payload": payload}

    # -- chief internals -----------------------------------------------------
    def _resolve_strategy(self) -> str:
        if self._cfg.grad_strategy != "auto":
            return self._cfg.grad_strategy
        total = grad_compression.grad_bytes(self._params)
        return ("int8_ef"
                if total >= self._cfg.compress_threshold_bytes else "dense")

    def _live_peers(self) -> list[tuple[str, Any]]:
        peers = []
        try:
            replicas = self._registry.lookup()["replicas"]
        except Exception:  # noqa: BLE001 - registry hiccup: step solo
            return []
        for r in replicas:
            if (r["load"].get("role") != "learner" or r["name"] == self._name
                    or r.get("draining")):
                continue
            cached = self._peer_clients.get(r["name"])
            if cached is None or cached[0] != r["endpoint"]:
                cached = (r["endpoint"], courier.client_for(r["endpoint"]))
                self._peer_clients[r["name"]] = cached
            peers.append((r["name"], cached[1]))
        return peers

    def _next_batch(self, ctx) -> Any:
        while not (ctx.should_stop or self._dead or self._retired):
            batch = self._batch_fn()
            if batch is not None:
                return batch
            ctx.wait_for_stop(0.02)
        return None

    def _publish(self) -> None:
        tree = {"params": host_tree(self._params),
                "opt": host_tree(self._opt), "ef": self._ef}
        self._store.publish_version(
            self._step, tree,
            metadata={"step": self._step, "loss": self._loss})
        self._published = self._step
        self._heartbeater.beat_now()   # version table updates immediately

    def _chief_step(self, ctx) -> bool:
        cfg = self._cfg
        strategy = self._resolve_strategy()
        peers = self._live_peers()
        payload_params = host_tree(self._params)
        fns = [lambda c=client: c.futures.compute_grads(
                   self._step, payload_params, strategy)
               for _, client in peers]
        batch = self._next_batch(ctx)
        if batch is None:
            return False
        loss, grads = self._grad_jit(self._params, batch)
        if strategy == "int8_ef":
            # Round-trip the local contribution through our own residual so
            # the aggregate is uniformly quantized and the published EF
            # state is the chief's real residual.
            payload, self._ef = grad_compression.compress_tree(
                grads, self._ef, method="int8_ef")
            contribs = [grad_compression.decompress_tree(payload)]
        else:
            contribs = [host_tree(grads)]
        losses = [float(loss)]

        results = hedged_map(fns, hedge_after_s=cfg.hedge_after_s,
                             quorum=len(fns) or None,
                             timeout_s=cfg.peer_timeout_s,
                             return_exceptions=True) if fns else []
        for (name, _), res in zip(peers, results):
            if res is None or isinstance(res, BaseException):
                # Peer failed or timed out: evict it so the next step's
                # quorum is over survivors only (it re-registers on its
                # next beat if it was a false alarm).
                try:
                    self._registry.report_failure(name)
                except Exception:  # noqa: BLE001
                    pass
                self._peer_clients.pop(name, None)
                continue
            contribs.append(grad_compression.decompress_tree(res["payload"]))
            losses.append(float(res["loss"]))

        n = len(contribs)
        avg = jax.tree.map(lambda *xs: sum(xs) / n, *contribs)
        self._params, self._opt, _ = opt_lib.apply_updates(
            self._task.optimizer, self._params, avg, self._opt)
        self._step += 1
        self._loss = float(np.mean(losses))
        self.history.append((self._step, self._loss))
        if (self._step % cfg.publish_every == 0
                or self._step >= cfg.total_steps):
            self._publish()
        return True

    # -- main loop -----------------------------------------------------------
    def run(self) -> None:
        ctx = get_current_context()
        if not self._chief:
            while not (ctx.should_stop or self._dead or self._retired):
                ctx.wait_for_stop(0.1)
            return
        t_last = time.monotonic()
        while (self._step < self._cfg.total_steps
               and not (ctx.should_stop or self._dead or self._retired)):
            stepped = self._chief_step(ctx)
            now = time.monotonic()
            if stepped:
                dt = max(now - t_last, 1e-9)
                inst = 1.0 / dt
                self._steps_per_s = (inst if self._steps_per_s == 0.0
                                     else 0.9 * self._steps_per_s + 0.1 * inst)
            t_last = now
        if self._step >= self._cfg.total_steps and not self._dead:
            self._done = True
            self._heartbeater.beat_now()
            # Keep heartbeating so the supervisor reads the done flag, but
            # our work is finished — wait for the program to wind down.
            while not (ctx.should_stop or self._dead or self._retired):
                ctx.wait_for_stop(0.05)


class ActorWorker:
    """Experience generator: pulls *published* params, writes replay.

    ``rollout_fn(params, rng) -> item`` produces one replay item.
    ``replay_resolver()`` returns a fresh replay client — called again
    after any insert failure, so a replay restart (or a stall caused by a
    dead learner) never wedges the actor: the typed ``WriterStalled``
    surfaces, the actor re-resolves and retries.
    """

    def __init__(self, task, rollout_fn: Callable[[Any, Any], Any],
                 replay_resolver: Callable[[], Any], table: str,
                 store_dir: str, registry: Any, cfg: FabricConfig, *,
                 name: str = "actor-0", endpoint: Optional[str] = None,
                 seed: int = 0):
        self._rollout_fn = rollout_fn
        self._resolver = replay_resolver
        self._table = table
        self._store = ModelStore(store_dir)
        self._cfg = cfg
        self._name = name
        self._like = task.init_params(jax.random.key(cfg.seed))
        self._params = self._like
        self._version: Optional[int] = None
        self._last_refresh = 0.0
        self._replay_client: Optional[Any] = None
        self._rng = np.random.default_rng(seed)
        self._dead = False
        self._inserts = 0
        self._stalls = 0
        self._errors = 0
        self._inserts_per_s = 0.0

        ctx = get_current_context()
        ep = endpoint or ctx.endpoint or f"inproc://{name}"
        self._heartbeater = Heartbeater(
            registry, name, ep, load_fn=self.load,
            period_s=cfg.heartbeat_s, stop_event=ctx.stop_event).start()

    def load(self) -> dict:
        return {"role": "actor", "version": self._version,
                "inserts": self._inserts, "stalls": self._stalls,
                "inserts_per_s": round(self._inserts_per_s, 3)}

    def telemetry(self) -> dict:
        return telemetry.telemetry_snapshot(service=self.load())

    def get_status(self) -> dict:
        if self._dead:
            raise ConnectionError(f"{self._name} is dead")
        return self.load()

    def kill(self) -> None:
        self._dead = True
        self._heartbeater.stop(deregister=False)

    def stall(self, seconds: float) -> None:
        self._heartbeater.pause(seconds)

    def _maybe_refresh(self) -> None:
        now = time.monotonic()
        if now - self._last_refresh < self._cfg.params_refresh_s:
            return
        self._last_refresh = now
        try:
            v = self._store.latest_version()
            if v is None or v == self._version:
                return
            tree = self._store.load_version(v, like={"params": self._like})
            self._params = tree["params"]
            self._version = v
        except Exception:  # noqa: BLE001 - version GC'd mid-read: next poll
            pass

    def _replay(self) -> Any:
        if self._replay_client is None:
            self._replay_client = self._resolver()
        return self._replay_client

    def run(self) -> None:
        ctx = get_current_context()
        t_last = time.monotonic()
        while not (ctx.should_stop or self._dead):
            self._maybe_refresh()
            item = self._rollout_fn(self._params, self._rng)
            try:
                ok = self._replay().insert(
                    self._table, item, 1.0, self._cfg.insert_timeout_s, True)
            except Exception as exc:  # noqa: BLE001
                if is_writer_stalled(exc):
                    # The sampler isn't draining (learner dead or lagging):
                    # fail over to a fresh handle instead of deadlocking.
                    self._stalls += 1
                else:
                    self._errors += 1
                self._replay_client = None
                ctx.wait_for_stop(0.05)
                continue
            if ok:
                self._inserts += 1
                now = time.monotonic()
                inst = 1.0 / max(now - t_last, 1e-9)
                self._inserts_per_s = (inst if self._inserts_per_s == 0.0
                                       else 0.9 * self._inserts_per_s
                                       + 0.1 * inst)
                t_last = now


class ReplayService(ReplayServer):
    """A ReplayServer that advertises itself in the registry (role=replay)
    so actors and learners can (re-)resolve it by role, and exposes the
    fault hooks chaos schedules expect."""

    def __init__(self, tables: list[TableConfig], registry: Any = None, *,
                 name: str = "replay", endpoint: Optional[str] = None,
                 heartbeat_s: float = 0.2):
        super().__init__(tables)
        self._name = name
        self._table_names = [t.name for t in tables]
        self._heartbeater = None
        if registry is not None:
            ctx = get_current_context()
            ep = endpoint or ctx.endpoint or f"inproc://{name}"
            self._heartbeater = Heartbeater(
                registry, name, ep, load_fn=self.load,
                period_s=heartbeat_s, stop_event=ctx.stop_event).start()

    def load(self) -> dict:
        totals = {"inserts": 0, "samples": 0, "size": 0}
        for t in self._table_names:
            s = self.stats(t)
            for k in totals:
                totals[k] += s[k]
        return {"role": "replay", **totals}

    def telemetry(self) -> dict:
        return telemetry.telemetry_snapshot(service=self.load())


class TrainSupervisor:
    """Membership-level resurrection for the training fleet.

    Stateless over the registry (like ``RolloutController``): every poll
    re-derives the live set and compares it against the expected roster
    ``{role: count}`` (worker ``i`` of a role is named ``{role}-{i}``). A
    missing worker is respawned through ``spawn_fn(name)`` under
    ``RestartPolicy`` backoff; ``scale(role, n)`` grows (spawn + elastic
    restore happens inside the worker ctor) or shrinks (graceful
    ``retire`` RPC + deregister) the set. With ``total_steps`` set, the
    supervisor stops the program once the chief reports done.
    """

    def __init__(self, registry: Any, spawn_fn: Callable[[str], Any],
                 expected: Optional[dict[str, int]] = None,
                 policy: RestartPolicy = RestartPolicy(max_restarts=5),
                 poll_s: float = 0.05, spawn_grace_s: float = 5.0,
                 total_steps: Optional[int] = None):
        self._registry = registry
        self._spawn_fn = spawn_fn
        self._expected = dict(expected or {})
        self._policy = policy
        self._poll_s = poll_s
        self._grace = spawn_grace_s
        self._total = total_steps
        self._restarts: dict[str, int] = {}
        self._spawned: set[str] = set()
        self._seen: set[str] = set()
        self._fatal: set[str] = set()
        self._hold_until: dict[str, float] = {}   # spawn in flight: wait
        self._pending: dict[str, float] = {}      # backoff: respawn at t
        self._logger = telemetry.get_logger()
        self.events: list[dict] = []
        self.done = False

    def _log(self, kind: str, name: str, **extra) -> None:
        self.events.append({"kind": kind, "name": name, **extra})
        self._logger.info(f"{kind} {name}", **extra)
        if kind in ("respawn", "fatal", "backoff", "spawn-failed",
                    "retire", "scale"):
            # Fabric events with causes: the hub collects these, so a
            # respawn storm is queryable after the fact, not just
            # scrolled-away stdout.
            telemetry.record_event(kind, cause=name,
                                   node=self._logger.node, **extra)

    def expected_names(self) -> list[str]:
        return [f"{role}-{i}" for role, n in sorted(self._expected.items())
                for i in range(n)]

    def scale(self, role: str, n: int) -> None:
        """Elastic resize; takes effect on the next poll."""
        old = self._expected.get(role, 0)
        self._expected[role] = int(n)
        self._log("scale", role, old=old, new=n)

    def stats(self) -> dict:
        return {"restarts": dict(self._restarts),
                "fatal": sorted(self._fatal),
                "expected": dict(self._expected), "done": self.done}

    def _retire_extras(self, live: dict) -> None:
        expected = set(self.expected_names())
        for name, rep in live.items():
            role = name.rsplit("-", 1)[0]
            if role not in self._expected or name in expected:
                continue
            try:
                courier.client_for(rep["endpoint"]).retire()
            except Exception:  # noqa: BLE001 - already gone is fine
                pass
            try:
                self._registry.deregister(name)
            except Exception:  # noqa: BLE001
                pass
            self._spawned.discard(name)
            self._seen.discard(name)
            self._restarts.pop(name, None)
            self._log("retire", name)

    def _spawn(self, name: str, restart: bool) -> None:
        try:
            self._spawn_fn(name)
        except Exception as exc:  # noqa: BLE001 - spawn failed: retry later
            self._log("spawn-failed", name, error=repr(exc))
            self._hold_until[name] = (time.monotonic()
                                      + self._policy.backoff_for(
                                          self._restarts.get(name, 0)))
            return
        self._spawned.add(name)
        self._hold_until[name] = time.monotonic() + self._grace
        self._log("respawn" if restart else "spawn", name,
                  restarts=self._restarts.get(name, 0))

    def _chief_done(self, live: dict) -> bool:
        for rep in live.values():
            load = rep.get("load", {})
            if load.get("role") == "learner" and load.get("chief"):
                if load.get("done"):
                    return True
                if self._total is not None and load.get("step", 0) >= self._total:
                    return True
        return False

    def poll(self) -> dict:
        now = time.monotonic()
        try:
            live = {r["name"]: r
                    for r in self._registry.lookup()["replicas"]}
        except Exception:  # noqa: BLE001 - registry down: nothing to decide
            return self.stats()
        self._seen |= set(live)
        for name in list(live):
            self._hold_until.pop(name, None)
            self._pending.pop(name, None)
        self._retire_extras(live)
        for name in self.expected_names():
            if name in live or name in self._fatal:
                continue
            if name in self._pending:                  # backoff running
                if now >= self._pending[name]:
                    del self._pending[name]
                    self._spawn(name, restart=True)
                continue
            if now < self._hold_until.get(name, 0.0):  # spawn coming up
                continue
            died = name in self._seen or name in self._spawned
            if not died:
                self._spawn(name, restart=False)       # initial roster fill
                continue
            r = self._restarts.get(name, 0)
            if not self._policy.allows(r):
                self._fatal.add(name)
                self._log("fatal", name, restarts=r)
                continue
            self._restarts[name] = r + 1
            wait = self._policy.backoff_for(r)
            if wait > 0:                               # crash-loop damping
                self._pending[name] = now + wait
                self._log("backoff", name, wait_s=round(wait, 3),
                          restarts=r + 1)
            else:
                self._spawn(name, restart=True)
        self.done = self._chief_done(live)
        return self.stats()

    def run(self) -> None:
        ctx = get_current_context()
        while not ctx.should_stop:
            self.poll()
            if self.done:
                ctx.stop_program()
                return
            ctx.wait_for_stop(self._poll_s)


class ThreadWorkerSpawner:
    """Hosts fabric workers on daemon threads behind inproc couriers.

    Each spawn gets a fresh inproc endpoint (incarnation-suffixed — inproc
    names are single-owner), its own ``WorkerContext``, and runs the
    worker's ``run()`` until it returns or ``stop_all`` fires. This is the
    thread launcher's analogue of the scheduler restarting an executable.
    """

    def __init__(self):
        self._incarnation = itertools.count()
        self._lock = threading.Lock()
        self._live: list[tuple[str, WorkerContext, threading.Thread]] = []

    def spawn(self, name: str,
              factory: Callable[[str, str], Any]) -> str:
        """Start ``factory(name, endpoint)`` on its own thread; returns the
        endpoint the worker serves on.

        Any still-running older incarnation of ``name`` is stopped first:
        a worker that merely *stalled* past its TTL (e.g. heartbeats
        starved during a long jit compile) must not keep training beside
        its replacement — the scheduler's restart semantics are that the
        old executable is gone.
        """
        with self._lock:
            for n, ctx_old, _ in self._live:
                if n == name:
                    ctx_old.stop_event.set()
        inproc = f"{name}.{next(self._incarnation)}"
        endpoint = f"inproc://{inproc}"
        ctx = WorkerContext(node_name=name)
        ctx.endpoint = endpoint

        def _main():
            set_current_context(ctx)
            log = telemetry.get_logger(name)
            try:
                worker = factory(name, endpoint)
            except Exception:  # noqa: BLE001 - supervisor retries the spawn
                log.exception("worker factory failed")
                return
            courier.inprocess.register(inproc, worker)
            try:
                run = getattr(worker, "run", None)
                if callable(run):
                    run()
                else:
                    # Passive services (e.g. replay) serve until stopped.
                    ctx.stop_event.wait()
            except Exception:  # noqa: BLE001 - a worker crash is a *fault*:
                # the supervisor resurrects it; the node-prefixed log (and
                # the recorded fabric event) make the respawn attributable
                # in interleaved fleet output.
                log.exception("worker crashed")
            finally:
                courier.inprocess.unregister(inproc)

        thread = threading.Thread(target=_main, daemon=True,
                                  name=f"fabric/{inproc}")
        with self._lock:
            self._live.append((name, ctx, thread))
        thread.start()
        return endpoint

    def stop_all(self, timeout_s: float = 5.0) -> None:
        with self._lock:
            live = list(self._live)
        for _, ctx, _ in live:
            ctx.stop_event.set()
        deadline = time.monotonic() + timeout_s
        for _, _, thread in live:
            thread.join(timeout=max(0.0, deadline - time.monotonic()))
