"""Gradient compression for the cross-pod (DCN) reduction.

Within a pod, gradient reductions ride ICI and stay uncompressed (XLA
collectives). *Across* pods the all-reduce crosses the data-center network
— the slow, contended link — so we expose an explicit compressed cross-pod
reduction:

  * bf16 reduction: cast-reduce-cast, 2× wire savings, error ≤ 2^-8 rel.
  * int8 + error feedback: per-tensor scale, 4× savings; the quantization
    residual is fed back into the next step's gradient (Seide et al.'s
    1-bit-SGD trick generalized), so the bias does not accumulate.

Implemented as a pure function over (grads, error_state) + a psum inside
``shard_map`` over the 'pod' axis; with one pod it degenerates to a no-op
so the same train step runs everywhere.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P


def _quantize_int8(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compress_reduce_pod(grads, error_state, mesh: Mesh,
                        method: str = "int8_ef", pod_axis: str = "pod"):
    """All-reduce ``grads`` over the pod axis with compression.

    grads: pytree of per-pod-averaged fp32 gradients (already reduced
    within the pod by XLA). error_state: pytree like grads (int8_ef) or
    None (bf16). Returns (reduced_grads, new_error_state).
    """
    if pod_axis not in mesh.axis_names or mesh.shape[pod_axis] == 1:
        return grads, error_state

    npod = mesh.shape[pod_axis]

    def _one(g, e):
        def inner(g_shard, e_shard):
            if method == "bf16":
                r = jax.lax.psum(g_shard.astype(jnp.bfloat16), pod_axis)
                return r.astype(jnp.float32) / npod, e_shard
            # int8 with error feedback
            corrected = g_shard + e_shard
            q, scale = _quantize_int8(corrected)
            deq = _dequantize(q, scale)
            new_err = corrected - deq          # what compression dropped
            # int8 psum overflows; sum dequantized fp32 (wire cost is the
            # int8 payload + one scalar — modeled in the roofline).
            r = jax.lax.psum(deq, pod_axis) / npod
            return r, new_err

        spec = P()  # per-pod replicated view of the (already FSDP'd) grad
        from repro.sharding.compat import shard_map
        fn = shard_map(inner, mesh=mesh,
                       in_specs=(spec, spec), out_specs=(spec, spec),
                       check_vma=False)
        return fn(g, e)

    if error_state is None:
        error_state = jax.tree.map(jnp.zeros_like, grads)
    out = jax.tree.map(_one, grads, error_state)
    reduced = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return reduced, new_err


def wire_bytes_saved(grads, method: str = "int8_ef") -> float:
    """Analytic DCN savings vs fp32 ring all-reduce (for §Perf records)."""
    total = sum(x.size * 4 for x in jax.tree.leaves(grads))
    factor = {"bf16": 2.0, "int8_ef": 4.0}[method]
    return total * (1 - 1 / factor)


# -- host/wire-side compression (actor-learner fabric) -----------------------
# The mesh path above compresses inside a shard_map psum. The training
# fabric's chief-driven aggregation instead ships per-learner gradients over
# courier RPC, so compression happens host-side on numpy trees: each learner
# quantizes its contribution with its *own* error-feedback residual, the
# chief dequantizes and averages. The residual is real training state — the
# chief's copy rides in published checkpoints and is resharded on elastic
# restores (see ckpt/elastic.py).

def select_strategy(tree, threshold_bytes: int = 1 << 22) -> str:
    """Pick the wire strategy by gradient size: below the threshold the
    dense fp32 payload is effectively free on a same-host courier, above it
    int8+EF buys 4x on the slow link."""
    total = sum(int(np.asarray(jax.device_get(x)).nbytes)
                for x in jax.tree.leaves(tree))
    return "int8_ef" if total >= threshold_bytes else "dense"


def grad_bytes(tree) -> int:
    return sum(int(np.asarray(jax.device_get(x)).nbytes)
               for x in jax.tree.leaves(tree))


def compress_tree(grads, error_state=None, method: str = "int8_ef"):
    """Compress a gradient pytree into a picklable wire payload.

    Returns ``(payload, new_error_state)``. ``method="dense"`` passes fp32
    through untouched (error_state is returned as-is); ``"int8_ef"`` applies
    per-tensor int8 quantization with error feedback, so the residual of
    what compression dropped is added back into the next step's gradient.
    """
    host = jax.tree.map(
        lambda x: np.asarray(jax.device_get(x), dtype=np.float32), grads)
    if method == "dense":
        return {"method": "dense", "tree": host}, error_state
    if method != "int8_ef":
        raise ValueError(f"unknown wire compression method {method!r}")
    if error_state is None:
        error_state = jax.tree.map(np.zeros_like, host)

    def _one(g, e):
        corrected = g + np.asarray(jax.device_get(e), dtype=np.float32)
        scale = np.float32(max(float(np.max(np.abs(corrected))), 1e-12) / 127.0)
        q = np.clip(np.rint(corrected / scale), -127, 127).astype(np.int8)
        residual = (corrected - q.astype(np.float32) * scale).astype(np.float32)
        return q, scale, residual

    out = jax.tree.map(_one, host, error_state)
    is_triple = lambda t: isinstance(t, tuple)  # noqa: E731
    payload = {
        "method": "int8_ef",
        "q": jax.tree.map(lambda t: t[0], out, is_leaf=is_triple),
        "scale": jax.tree.map(lambda t: t[1], out, is_leaf=is_triple),
    }
    new_err = jax.tree.map(lambda t: t[2], out, is_leaf=is_triple)
    return payload, new_err


def decompress_tree(payload):
    """Inverse of ``compress_tree``: payload -> fp32 numpy gradient tree."""
    if payload["method"] == "dense":
        return payload["tree"]
    return jax.tree.map(lambda q, s: q.astype(np.float32) * s,
                        payload["q"], payload["scale"])
