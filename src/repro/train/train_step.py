"""Training step factory: remat + microbatch accumulation + AdamW.

``make_train_step`` builds a jit-able ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` for a given model/optimizer config.
Microbatching runs under ``lax.scan`` accumulating fp32 grads so arbitrary
global batches fit; remat policy controls the activation-memory/compute
trade (hillclimbed per-cell in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: opt_lib.OptimizerConfig = opt_lib.OptimizerConfig()
    num_microbatches: int = 1
    remat: str = "full"            # none | full | dots
    grad_accum_dtype: str = "float32"
    resid_tp: bool = False         # shard saved residuals over TP (FSDP+SP)
    # Unroll the microbatch loop in python instead of lax.scan. Production
    # keeps scan (bounded HLO); the roofline probes unroll so per-microbatch
    # weight gathers/reduce-scatters are visible to XLA cost analysis.
    unroll_micro: bool = False


def _remat_flag(policy: str) -> bool:
    return policy != "none"


def split_batch(batch: dict, num_micro: int) -> dict:
    """[B, ...] -> [num_micro, B/num_micro, ...]."""
    def f(x):
        B = x.shape[0]
        assert B % num_micro == 0, (B, num_micro)
        return x.reshape(num_micro, B // num_micro, *x.shape[1:])
    return jax.tree.map(f, batch)


def make_loss_fn(model_cfg: ModelConfig, remat: str, resid_tp: bool = False):
    use_remat = _remat_flag(remat)

    def loss_fn(params, micro_batch):
        return transformer.loss_fn(model_cfg, params, micro_batch,
                                   remat=use_remat, resid_tp=resid_tp)
    return loss_fn


def make_grad_fn(model_cfg: ModelConfig, train_cfg: TrainConfig):
    """jit-able ``(params, batch) -> (loss, aux, grads)`` with microbatch
    accumulation — the gradient half of ``make_train_step``, exposed so
    the training fabric can aggregate gradients across learners before
    applying the update."""
    loss_fn = make_loss_fn(model_cfg, train_cfg.remat, train_cfg.resid_tp)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    nm = train_cfg.num_microbatches
    acc_dt = jnp.dtype(train_cfg.grad_accum_dtype)

    def compute_grads(params, batch):
        if nm == 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, aux, grads
        micro = split_batch(batch, nm)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)

        def body(carry, mb):
            loss_acc, g_acc = carry
            (loss, _aux), g = grad_fn(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(acc_dt), g_acc, g)
            return (loss_acc + loss, g_acc), None

        if train_cfg.unroll_micro:
            carry = (jnp.zeros((), jnp.float32), g0)
            for i in range(nm):
                carry, _ = body(carry, jax.tree.map(lambda x: x[i], micro))
            loss_sum, grads = carry
        else:
            (loss_sum, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), g0), micro)
        grads = jax.tree.map(lambda g: (g / nm).astype(jnp.float32), grads)
        loss = loss_sum / nm
        return loss, {"ce": loss, "aux": jnp.zeros(())}, grads

    return compute_grads


def make_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig):
    compute_grads = make_grad_fn(model_cfg, train_cfg)

    def train_step(params, opt_state, batch):
        loss, aux, grads = compute_grads(params, batch)
        params, opt_state, om = opt_lib.apply_updates(
            train_cfg.optimizer, params, grads, opt_state)
        metrics = {"loss": loss, **aux, **om}
        return params, opt_state, metrics

    return train_step


def make_train_state(model_cfg: ModelConfig, key):
    params = transformer.init_params(model_cfg, key)
    return params, opt_lib.init_opt_state(params)


def train_state_shapes(model_cfg: ModelConfig):
    """Abstract (params, opt_state) for the dry-run — no allocation."""
    return jax.eval_shape(
        functools.partial(make_train_state, model_cfg), jax.random.key(0))
