"""AdamW + global-norm clipping + warmup-cosine schedule (pure JAX).

Optimizer moments live in the same sharding as their parameters (the
param_sharding rules apply to the ``m``/``v`` trees verbatim), so optimizer
state is fully FSDP-sharded.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> dict:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _decay_mask(path) -> bool:
    """Weight decay on matrices only (no norms/biases/scalars)."""
    last = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return last not in ("bias", "scale", "lam", "A_log", "D", "bias_a", "bias_x")


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay and _decay_mask(path):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * u).astype(p.dtype), m, v

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
